"""Serving-traffic benchmark: continuous batching vs sequential generate().

Drives the slot scheduler (serve.Scheduler / serve.Server) with a Poisson
stream of mixed-prompt-length, mixed-temperature requests and measures what
a traffic-serving deployment cares about:

 * goodput (emitted tokens per wall second) vs the one-request-at-a-time
   ``generate()`` baseline over the SAME workload (same prompts, keys,
   temperatures — the sequential pass doubles as the token-parity oracle:
   continuous batching must emit bit-identical tokens per request),
 * per-token latency p50/p95,
 * slot occupancy (mean + steady-state while demand is backed up),
 * probe-union dedup ratio U/(Q*n_probe) vs batch fill — the amortization
   argument for retrieval-based estimators under load,
 * recompiles after warmup (must be ZERO: one compiled mixed step serves
   every admission/replay/decode mix),
 * an OVERLOAD scenario (2x sustained demand vs slot capacity, deterministic
   trace on the virtual clock) through the bounded queue + degradation
   ladder: shed rate, p95 under overload, fraction of tokens served from a
   degraded tier, peak queue depth — still with zero recompiles, since
   every ladder tier is compiled once during warmup,
 * a RAW-SPEED section (DESIGN.md SS16): estimator-speculative decoding
   (cheap registry tier drafts k tokens, the serving tier verifies them in
   one batched pass) and the shared-prefix KV cache, both on a bursty
   shared-system-prompt trace — speculative goodput must beat
   non-speculative and the warm cache must save replay steps, still with
   bit-identical tokens and zero recompiles,
 * a SCALING curve for the mesh-sharded scheduler step (DESIGN.md SS15):
   goodput / p95 / occupancy at 1/2/4/8 virtual devices, one subprocess
   per (data, model) mesh shape, with token parity vs solo generate() and
   zero recompiles required at every shape (see ``_scaling``).

Writes BENCH_serving.json; gated by ``benchmarks/run.py --check``.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np


def _build(quick: bool, mesh=None):
    import dataclasses

    from repro.configs import reduced_config
    from repro.models import Model
    from repro.serve import Engine

    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=2048 if quick else 8192,
        partition=dataclasses.replace(cfg.partition, method="mimps",
                                      block_rows=128, n_probe=4, l=128))
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    gen = 8 if quick else 16
    p_max = 12 if quick else 24
    eng = Engine(model, params, max_len=p_max + gen + 1, key=key, mesh=mesh)
    return eng, cfg, gen, p_max


def _workload(cfg, n_req: int, gen: int, p_lens, seed: int = 0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_req):
        p_len = p_lens[i % len(p_lens)]
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab, size=(p_len,), dtype=np.int32),
            max_new_tokens=gen,
            key=jax.random.PRNGKey(7_000 + i),
            temperature=0.0 if i % 2 == 0 else 0.8))
    return reqs


def _shared_prefix_workload(cfg, n_req: int, gen: int, shared_len: int,
                            tail_lens, seed: int = 11):
    """Every request shares one system-prompt prefix (the prefix-cache /
    speculation scenario: agents, RAG templates, few-shot headers)."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, size=(shared_len,), dtype=np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab,
                            size=(tail_lens[i % len(tail_lens)],),
                            dtype=np.int32)
        reqs.append(Request(
            prompt=np.concatenate([shared, tail]),
            max_new_tokens=gen,
            key=jax.random.PRNGKey(9_000 + i),
            temperature=0.0 if i % 2 == 0 else 0.8))
    return reqs


def _sequential(eng, reqs, time_it: bool):
    """One-request-at-a-time generate() over the workload. Returns
    (tokens_per_request, wall_seconds). Compile buckets are warmed by the
    caller running this once with time_it=False first."""
    from repro.serve import generate
    import time
    outs = []
    t0 = time.perf_counter()
    for r in reqs:
        toks = generate(eng, jnp.asarray(r.prompt)[None], r.max_new_tokens,
                        r.key, temperature=r.temperature)
        outs.append([int(t) for t in np.asarray(jax.device_get(toks))[0]])
    dt = time.perf_counter() - t0
    return outs, (dt if time_it else float("nan"))


def _overload(sched, cfg, n_slots: int, n_req: int, gen: int, p_lens):
    """2x sustained demand vs slot capacity through the overload policy.

    Demand is a deterministic trace on the virtual step clock (capacity
    digests ~n_slots/gen requests per step; arrivals come at twice that),
    so the shed/degrade/restore path replays identically run to run. Every
    ladder tier is warmed (compiled) on a throwaway workload FIRST, so the
    measured section must not trace anything new.
    """
    from repro.configs import ServingConfig
    from repro.serve import Server, default_ladder, trace_arrivals

    base_tier = sched.tier
    for tier in default_ladder(base_tier):
        sched.set_tier(tier)
        warm = Server(sched)
        for r in _workload(cfg, 2, 2, [3, 5], seed=98):
            warm.submit(r)
        warm.run()
    sched.set_tier(base_tier)
    traces0 = (sched.step_traces, sched.admit_traces)

    ov_reqs = _workload(cfg, 2 * n_req, gen, p_lens, seed=7)
    rate = 2.0 * n_slots / gen      # requests per virtual step = 2x capacity
    arrivals = trace_arrivals(ov_reqs, [i / rate for i in range(len(ov_reqs))])
    ov_cfg = ServingConfig(max_queue=n_slots,
                           degrade_high=max(2, n_slots // 2),
                           degrade_low=1, degrade_after=2, restore_after=6)
    # observability rides the measured overload run fully enabled (trace +
    # snapshot + shadow sampling): the CI artifacts come from here, and the
    # run must STILL trace nothing new — obs state is data, not shape.
    import os

    from repro.obs import Observability, ObsConfig
    os.makedirs("artifacts", exist_ok=True)
    obs = Observability(ObsConfig(
        harvest_every=8, shadow_every=4, snapshot_every=1,
        trace_path=os.path.join("artifacts", "serving_trace.jsonl"),
        snapshot_path=os.path.join("artifacts", "metrics_snapshot.json")))
    sched.reset_metrics()
    rep = Server(sched, ov_cfg, obs=obs).run(arrivals=arrivals)
    recompiles = (sched.step_traces - traces0[0]) + \
        (sched.admit_traces - traces0[1])
    assert len(rep.completions) == len(ov_reqs), "overload accounting leak"
    h = obs.last_harvest
    obs.close()
    sched.engine.obs = None
    sched.shadow_every = 0
    # the device counters were reset right before the measured run, so the
    # harvested per-tier token counts must reconcile exactly with the
    # host-side report — one acceptance criterion of the obs layer
    harvested_by_tier = {t: v for t, v in h["tokens_by_tier"].items() if v}
    reconciled = harvested_by_tier == {
        t: v for t, v in dict(rep.tokens_by_tier).items() if v}
    shadow = {t: s for t, s in h["shadow_by_tier"].items() if s["count"]}
    return {
        "obs": {
            "trace_path": obs.cfg.trace_path,
            "trace_events": obs.tracer.events_written,
            "snapshot_path": obs.cfg.snapshot_path,
            "tokens_by_tier_harvested": harvested_by_tier,
            "tokens_reconciled": bool(reconciled),
            "shadow_rel_err_by_tier": {
                t: {"count": s["count"],
                    "rel_err_mean": s["rel_err_mean"],
                    "rel_err_max": s["rel_err_max"]}
                for t, s in shadow.items()},
        },
        "n_req": len(ov_reqs),
        "demand_x_capacity": 2.0,
        "max_queue": ov_cfg.max_queue,
        "ladder": list(default_ladder(base_tier)),
        "shed_rate": rep.shed_rate,
        "rejects_by_reason": dict(rep.rejects_by_reason),
        "p95_under_overload": rep.p95_token_ms,
        "degraded_token_frac": rep.degraded_token_frac,
        "tokens_by_tier": dict(rep.tokens_by_tier),
        "tier_transitions": [[int(s), t] for s, t in rep.tier_transitions],
        "queue_depth_peak": int(rep.queue_depth_peak),
        "goodput_tok_s": rep.goodput_tok_s,
        "recompiles_after_warmup": int(recompiles),
    }


def _obs_overhead(sched, cfg, n_req: int, gen: int, p_lens):
    """Observability tax: the SAME workload served with the obs layer fully
    enabled (harvest + shadow sampling + trace + snapshot) vs disabled,
    interleaved 5x each (best-of-N per arm damps shared-host noise).

    Gated by run.py --check: goodput ratio on >= 0.95 of off, tokens
    bit-identical between the arms, and zero recompiles across the whole
    section — the executable must not know whether obs is watching (the
    metric state is always threaded; cadence flags are traced data).
    """
    import os
    import tempfile

    from repro.obs import Observability, ObsConfig
    from repro.serve import Server, trace_arrivals

    tmp = tempfile.mkdtemp(prefix="obs_overhead_")
    traces0 = (sched.step_traces, sched.admit_traces)
    best = {"on": 0.0, "off": 0.0}
    tokens_ref, parity = None, True
    for trial in range(5):
        for mode in ("off", "on"):
            obs = None
            if mode == "on":
                # serve-CLI default cadences: fully on means trace +
                # metrics + shadow + snapshots, not a stress cadence
                obs = Observability(ObsConfig(
                    harvest_every=16, shadow_every=16, snapshot_every=4,
                    trace_path=os.path.join(tmp, "trace.jsonl"),
                    snapshot_path=os.path.join(tmp, "snap.json")))
            else:
                # detach anything a previous on-arm left behind
                sched.shadow_every = 0
                sched.engine.obs = None
            reqs = _workload(cfg, n_req, gen, p_lens, seed=5)
            rep = Server(sched, obs=obs).run(
                arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
            if obs is not None:
                obs.close()
            # req_ids are globally fresh per trial: compare positionally
            by_id = {c.request.req_id: c.tokens for c in rep.completions}
            got = [by_id.get(r.req_id) for r in reqs]
            if tokens_ref is None:
                tokens_ref = got
            else:
                parity = parity and got == tokens_ref
            best[mode] = max(best[mode], rep.goodput_tok_s)
    sched.shadow_every = 0
    sched.engine.obs = None
    recompiles = (sched.step_traces - traces0[0]) + \
        (sched.admit_traces - traces0[1])
    row = {
        "goodput_on_tok_s": best["on"],
        "goodput_off_tok_s": best["off"],
        "goodput_ratio_on_vs_off": best["on"] / max(best["off"], 1e-9),
        "token_parity_on_vs_off": bool(parity),
        "recompiles_after_warmup": int(recompiles),
    }
    print(f"  obs on {best['on']:.0f} tok/s vs off {best['off']:.0f} "
          f"({row['goodput_ratio_on_vs_off']:.3f}x), parity {parity}, "
          f"recompiles {recompiles}", flush=True)
    return row


def _raw_speed(quick: bool):
    """DESIGN.md SS16: estimator-speculative decoding + shared-prefix KV
    cache on a bursty shared-system-prompt trace (all-at-once arrivals on
    the virtual clock, so step counts are deterministic).

    This section runs its own engine in the regime the paper targets —
    a LARGE vocab with the EXACT tier serving (the output layer dominates
    the step) — because that is where speculation's economics live: the
    sublinear estimator drafts k tokens nearly for free, then ONE exact
    pass verifies all k positions while streaming the (V, d) embedding
    once, instead of k sequential exact passes streaming it k times. At
    the small-vocab mimps operating point of the main serving section the
    trunk forward dominates and is shared by draft and verify, so
    speculation only rearranges step overhead (tokens-per-step still
    improves ~2x; wall clock does not — measured, not hidden).

    Every configuration must keep the two hard invariants (bit-identical
    tokens vs solo generate(), zero recompiles after warmup); the perf
    claims gated by ``run.py --check`` are (a) speculative goodput beats
    non-speculative on this scenario for at least one registry draft
    (wall clock AND tokens per virtual step), and (b) the prefix cache
    saves replay steps (> 0) once warm.
    """
    import dataclasses

    from repro.configs import reduced_config
    from repro.models import Model
    from repro.serve import Engine, Scheduler, Server, trace_arrivals

    cfg = reduced_config("qwen1.5-4b")
    cfg = dataclasses.replace(
        cfg, vocab=32768, partition=dataclasses.replace(
            cfg.partition, method="exact", block_rows=128, n_probe=4,
            l=128))
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    gen, p_max = 8, 12
    eng = Engine(model, model.init(key), max_len=p_max + gen + 1, key=key)

    n_slots = 8
    n_req = 2 * n_slots if quick else 4 * n_slots
    shared_len = p_max - 4
    tails = [1, 2, 3, 4]
    bt = 4
    spec_k = 4
    oracle, _ = _sequential(
        eng, _shared_prefix_workload(cfg, n_req, gen, shared_len, tails),
        time_it=False)

    def serve(spec_draft=None, blocks=0):
        sched = Scheduler(eng, n_slots=n_slots, key=jax.random.PRNGKey(2),
                          spec_draft=spec_draft,
                          spec_k=spec_k if spec_draft else 1,
                          prefix_cache_blocks=blocks,
                          prefix_block_tokens=bt)
        warm = Server(sched)
        for r in _workload(cfg, 2, 2, [3, 5], seed=97):
            warm.submit(r)
        warm.run()
        traces0 = (sched.step_traces, sched.admit_traces)
        reps, parity = [], True
        for _ in range(2):   # 2nd pass also runs against a warm prefix pool
            reqs = _shared_prefix_workload(cfg, n_req, gen, shared_len,
                                           tails)
            rep = Server(sched).run(
                arrivals=trace_arrivals(reqs, [0.0] * len(reqs)))
            got = {c.request.req_id: c.tokens for c in rep.completions}
            parity = parity and all(got.get(r.req_id) == oracle[i]
                                    for i, r in enumerate(reqs))
            reps.append(rep)
        recompiles = (sched.step_traces - traces0[0]) + \
            (sched.admit_traces - traces0[1])
        # goodput: best of 2 (damps shared-host noise); steps: the warm
        # min (deterministic on the virtual clock, so it is what the
        # --check gate compares)
        best = max(reps, key=lambda r: r.goodput_tok_s)
        steps = min(r.steps for r in reps)
        total = sum(len(c.tokens) for c in best.completions)
        row = {
            "goodput_tok_s": best.goodput_tok_s,
            "steps": int(steps),
            "tok_per_step": total / max(steps, 1),
            "token_parity": bool(parity),
            "recompiles_after_warmup": int(recompiles),
        }
        if spec_draft:
            row["acceptance"] = best.spec_acceptance
            row["draft_flagged"] = int(best.draft_flagged)
        if blocks:
            row["prefix"] = dict(sched.prefix.stats())
        return row

    base = serve()
    drafts = {d: serve(spec_draft=d) for d in ("topk", "fmbe")}
    for name, r in drafts.items():
        print(f"  spec draft={name} k={spec_k}: "
              f"{r['goodput_tok_s']:.0f} tok/s ({r['tok_per_step']:.1f}"
              f"/step) vs non-spec {base['goodput_tok_s']:.0f} "
              f"({base['tok_per_step']:.1f}/step), acceptance "
              f"{r['acceptance']:.2f}, parity {r['token_parity']}, "
              f"recompiles {r['recompiles_after_warmup']}", flush=True)
    blocks = 8 * n_slots
    cache_on = serve(blocks=blocks)
    combined = serve(spec_draft="topk", blocks=blocks)
    print(f"  prefix cache ({blocks} blocks x {bt} tok): "
          f"{cache_on['steps']} steps vs {base['steps']} off, saved "
          f"{cache_on['prefix']['saved_steps']} replay steps "
          f"({cache_on['prefix']['hits']} hits); spec+cache "
          f"{combined['goodput_tok_s']:.0f} tok/s", flush=True)
    spec = {
        "scenario": {"n_req": n_req, "shared_prefix_len": shared_len,
                     "tail_lens": tails, "gen": gen, "spec_k": spec_k,
                     "vocab": cfg.vocab, "serving_tier": "exact"},
        "nonspec": base,
        "drafts": drafts,
        "speedup_vs_nonspec": max(
            r["goodput_tok_s"] for r in drafts.values())
            / base["goodput_tok_s"],
        "with_prefix_cache": combined,
    }
    prefix = {
        "blocks": blocks, "block_tokens": bt,
        "off": {k: base[k] for k in ("goodput_tok_s", "steps",
                                     "tok_per_step")},
        "on": {k: cache_on[k] for k in ("goodput_tok_s", "steps",
                                        "tok_per_step")},
        "hits": cache_on["prefix"]["hits"],
        "saved_replay_steps": cache_on["prefix"]["saved_steps"],
        "evictions": cache_on["prefix"]["evictions"],
        "token_parity": cache_on["token_parity"],
        "recompiles_after_warmup": cache_on["recompiles_after_warmup"],
    }
    return spec, prefix


def _scaling_child(data: int, model: int, quick: bool = True):
    """One scaling-curve row. Runs in a SUBPROCESS whose environment sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax is
    imported (the parent process owns a single-device jax runtime).

    Builds a (data, model)-mesh engine with ``lanes_per_replica * data``
    slot lanes, warms the scheduler, serves a saturating all-at-once trace
    twice (best-of-2 goodput damps scheduler-noise on a shared host), and
    checks the two hard invariants per row: tokens bit-identical to a
    single-device solo ``generate()`` oracle, and zero retraces after
    warmup. Emits one ``SCALING::{json}`` line on stdout for the parent.
    """
    from repro.launch.mesh import make_serving_mesh
    from repro.serve import Scheduler, Server, trace_arrivals

    mesh = make_serving_mesh(data=data, model=model)
    eng, cfg, gen, p_max = _build(quick, mesh=mesh)
    lanes = 4 if quick else 8
    n_slots = lanes * data
    n_req = 4 * n_slots
    p_lens = [4, 6, 9, 12] if quick else [4, 8, 12, 17, 24]

    # parity oracle: an UNMESHED engine in the same process (same params —
    # Model.init is deterministic in the config + key)
    solo_eng, _, _, _ = _build(quick, mesh=None)
    oracle, _ = _sequential(solo_eng, _workload(cfg, n_req, gen, p_lens,
                                                seed=3), time_it=False)

    sched = Scheduler(eng, n_slots=n_slots, key=jax.random.PRNGKey(1))
    warm = Server(sched)
    for r in _workload(cfg, 2, 2, [3, 5], seed=99):
        warm.submit(r)
    warm.run()
    traces0 = (sched.step_traces, sched.admit_traces)

    goodput, parity = 0.0, True
    rep = None
    for _ in range(2):
        wl = _workload(cfg, n_req, gen, p_lens, seed=3)
        server = Server(sched)
        rep = server.run(arrivals=trace_arrivals(wl, [0.0] * len(wl)))
        got = {c.request.req_id: c.tokens for c in rep.completions}
        parity = parity and all(got.get(r.req_id) == oracle[i]
                                for i, r in enumerate(wl))
        goodput = max(goodput, rep.goodput_tok_s)
    recompiles = (sched.step_traces - traces0[0]) + \
        (sched.admit_traces - traces0[1])
    total_tokens = sum(len(c.tokens) for c in rep.completions)
    row = {
        "data": data, "model": model, "devices": data * model,
        "n_slots": n_slots, "n_req": n_req,
        # virtual-step-clock goodput: tokens emitted per compiled scheduler
        # step. This is the quantity the mesh scales (one step serves
        # data*lanes slot lanes) and the one a virtual-device run can
        # certify honestly — see _scaling's docstring.
        "tok_per_step": total_tokens / max(rep.steps, 1),
        "steps": rep.steps,
        "goodput_tok_s": goodput,
        "p95_token_ms": rep.p95_token_ms,
        "occupancy_steady": rep.occupancy_steady,
        "token_parity": bool(parity),
        "recompiles_after_warmup": int(recompiles),
    }
    print("SCALING::" + json.dumps(row), flush=True)


def _scaling(quick: bool = True):
    """Goodput-vs-device-count curve for the mesh-sharded scheduler step.

    Each row runs in its own subprocess so the 8-virtual-device XLA_FLAGS
    can be set before jax import. The data-only chain (1,1)->(8,1) is the
    scaling curve proper — lanes per replica held fixed, total slot lanes
    grow with the data extent; (2,2) exercises the model-sharded output
    layer inside the same serving step.

    The GATED metric is ``tok_per_step`` on the virtual step clock (the
    same clock the overload trace uses): one compiled step must serve
    data*lanes slot lanes, so tokens-per-step scales with the data extent
    — that is the scaling property a forced-host-device run can certify.
    Wall-clock ``goodput_tok_s`` is recorded per row but NOT gated for
    monotonicity: the 8 virtual devices time-share however many physical
    cores the host has (possibly one), so wall clock measures core
    contention, not the per-replica-per-chip deployment this mesh maps to.
    """
    import os
    import subprocess
    import sys

    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), here]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    shapes = [(1, 1), (2, 1), (4, 1), (8, 1), (2, 2)]
    rows = []
    for d, m in shapes:
        code = (f"import serving_bench; "
                f"serving_bench._scaling_child({d}, {m}, {quick})")
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=1800)
        line = next((ln for ln in proc.stdout.splitlines()
                     if ln.startswith("SCALING::")), None)
        if proc.returncode != 0 or line is None:
            raise RuntimeError(
                f"scaling row data={d},model={m} failed:\n"
                f"{proc.stdout}\n{proc.stderr}")
        rows.append(json.loads(line[len("SCALING::"):]))
        r = rows[-1]
        print(f"  mesh data={d},model={m}: {r['tok_per_step']:.1f} "
              f"tok/step ({r['goodput_tok_s']:.0f} tok/s wall), p95 "
              f"{r['p95_token_ms']:.2f}ms, parity {r['token_parity']}, "
              f"recompiles {r['recompiles_after_warmup']}", flush=True)
    chain = [r["tok_per_step"] for r in rows if r["model"] == 1]
    return {
        "lanes_per_replica": rows[0]["n_slots"],
        "clock": "virtual-step",
        "rows": rows,
        "goodput_monotone": all(b >= a for a, b in zip(chain, chain[1:])),
        "goodput_scaling_8v1": chain[-1] / chain[0],
    }


def run(quick: bool = True):
    from repro.serve import Scheduler, Server, poisson_arrivals

    eng, cfg, gen, p_max = _build(quick)
    n_slots = 8 if quick else 16
    n_req = 16 if quick else 64
    p_lens = [4, 6, 9, 12] if quick else [4, 8, 12, 17, 24]
    reqs = _workload(cfg, n_req, gen, p_lens)

    # -- sequential baseline (also the parity oracle). First pass warms every
    #    (bucket, n_tokens) scan compile; second pass is the measurement.
    _sequential(eng, reqs, time_it=False)
    seq_tokens, seq_wall = _sequential(eng, reqs, time_it=True)
    seq_goodput = sum(len(t) for t in seq_tokens) / seq_wall

    # -- continuous batching. Warm the scheduler's two executables on a
    #    throwaway workload, then reset bookkeeping and serve the real one.
    sched = Scheduler(eng, n_slots=n_slots, key=jax.random.PRNGKey(1))
    warm = Server(sched)
    for r in _workload(cfg, 2, 2, [3, 5], seed=99):
        warm.submit(r)
    warm.run()
    traces_after_warmup = (sched.step_traces, sched.admit_traces)
    sched.reset_metrics()   # device counters start clean for the latency rows

    server = Server(sched)
    arrivals = poisson_arrivals(reqs, rate=2.0, seed=0)
    rep = server.run(arrivals=arrivals)
    recompiles = (sched.step_traces - traces_after_warmup[0]) + \
        (sched.admit_traces - traces_after_warmup[1])
    mh = sched.harvest_metrics()

    got = {c.request.req_id: c.tokens for c in rep.completions}
    parity = all(got.get(r.req_id) == seq_tokens[i]
                 for i, r in enumerate(reqs))
    # concurrency actually reached (acceptance: benefits at >= 8 in flight)
    peak_active = rep.peak_concurrency

    report = {
        "config": {"vocab": cfg.vocab, "n_slots": n_slots, "n_req": n_req,
                   "gen": gen, "prompt_lens": p_lens,
                   "method": cfg.partition.method, "quick": quick},
        "goodput_tok_s": rep.goodput_tok_s,
        "sequential_goodput_tok_s": seq_goodput,
        "speedup_vs_sequential": rep.goodput_tok_s / seq_goodput,
        "p50_token_ms": rep.p50_token_ms,
        "p95_token_ms": rep.p95_token_ms,
        "occupancy_mean": rep.occupancy_mean,
        "occupancy_steady": rep.occupancy_steady,
        "peak_concurrency": int(peak_active),
        "dedup_ratio_mean": rep.dedup_ratio_mean,
        # sorted [fill, ratio] rows — JSON objects would stringify the int
        # keys ("1".."8") and scramble their order
        "dedup_by_fill": [[int(k), float(v)] for k, v in
                          sorted(rep.dedup_by_fill.items())],
        "queue_wait_steps_mean": rep.queue_wait_steps_mean,
        "steps": rep.steps,
        "wall_s": rep.wall_s,
        "token_parity_vs_solo": bool(parity),
        "recompiles_after_warmup": int(recompiles),
    }
    # latency rows (obs satellite): host-percentile tail + the device-side
    # per-tier step-latency histogram harvested from the metric-state pytree
    # the compiled step threads. Buckets are emitted CUMULATIVE (Prometheus
    # histogram convention) so run.py --check can gate monotonicity.
    report["latency"] = {
        "p50_token_ms": rep.p50_token_ms,
        "p95_token_ms": rep.p95_token_ms,
        "p99_token_ms": rep.p99_token_ms,
        "step_device_ms_mean": rep.step_device_ms_mean,
        "step_host_ms_mean": rep.step_host_ms_mean,
        "edges_ms": list(mh["latency_edges_ms"]),
        "per_tier_cumulative": {
            tier: [int(c) for c in np.cumsum(counts)]
            for tier, counts in mh["latency_hist_by_tier"].items()
            if sum(counts)},
    }
    print("observability overhead (obs fully on vs off, best of 5 "
          "interleaved):", flush=True)
    report["obs_overhead"] = _obs_overhead(sched, cfg, n_req, gen, p_lens)
    report["overload"] = _overload(sched, cfg, n_slots, n_req, gen, p_lens)
    print("raw speed (speculation + prefix cache, shared-prefix trace, "
          "exact tier @ 32k vocab):", flush=True)
    report["spec"], report["prefix_cache"] = _raw_speed(quick)
    print("scaling curve (subprocess per mesh shape):", flush=True)
    report["scaling"] = _scaling(quick)
    with open("BENCH_serving.json", "w") as f:
        json.dump(report, f, indent=2)
    total_tokens = sum(len(t) for t in seq_tokens)
    us_per_token = rep.wall_s / max(total_tokens, 1) * 1e6
    print(f"serving: goodput {rep.goodput_tok_s:.0f} tok/s vs sequential "
          f"{seq_goodput:.0f} ({report['speedup_vs_sequential']:.2f}x), "
          f"occupancy {rep.occupancy_steady:.2f}, parity {parity}, "
          f"recompiles {recompiles}")
    ov = report["overload"]
    print(f"overload (2x demand): shed_rate {ov['shed_rate']:.2f}, "
          f"p95 {ov['p95_under_overload']:.2f}ms, degraded_token_frac "
          f"{ov['degraded_token_frac']:.2f}, queue_depth_peak "
          f"{ov['queue_depth_peak']}, recompiles "
          f"{ov['recompiles_after_warmup']}")
    sp, pc = report["spec"], report["prefix_cache"]
    print(f"raw speed: spec {sp['speedup_vs_nonspec']:.2f}x non-spec "
          f"goodput (topk acceptance "
          f"{sp['drafts']['topk']['acceptance']:.2f}), prefix cache saved "
          f"{pc['saved_replay_steps']} replay steps ({pc['hits']} hits, "
          f"{pc['on']['steps']} vs {pc['off']['steps']} steps)")
    sc = report["scaling"]
    print(f"scaling: tok/step @8dev vs @1dev "
          f"{sc['goodput_scaling_8v1']:.2f}x, monotone "
          f"{sc['goodput_monotone']}")
    return report, us_per_token


if __name__ == "__main__":
    run()
