"""Paper Table 1: mean absolute relative error mu (+ std err sigma) for
UNIFORM / MIMPS / MINCE over the (k, l) hyper-parameter grid."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exact_log_z, mimps_log_z, mince_log_z, uniform_log_z

from .common import make_embeddings, make_queries, pct_abs_rel_error


def run(n=20000, d=64, n_queries=100, seeds=(0, 1, 2), quick=False):
    if quick:
        n, n_queries, seeds = 8000, 50, (0, 1)
    ks = [1000, 100, 10, 1]
    ls = [1000, 100, 10]
    rows = []
    t0 = time.perf_counter()
    for seed in seeds:
        key = jax.random.PRNGKey(seed)
        kv, kq, ke = jax.random.split(key, 3)
        v = make_embeddings(kv, n, d)
        q, _ = make_queries(kq, v, n_queries)
        lz_true = jax.vmap(lambda qq: exact_log_z(v, qq))(q)
        keys = jax.random.split(ke, n_queries)

        for l in ls:
            lz = jax.vmap(lambda qq, kk: uniform_log_z(v, qq, l, kk))(q, keys)
            rows.append(("Uniform", 0, l, seed,
                         pct_abs_rel_error(lz, lz_true)))
        for k in ks:
            for l in ls:
                lz = jax.vmap(lambda qq, kk: mimps_log_z(v, qq, k, l, kk))(
                    q, keys)
                rows.append(("MIMPS", k, l, seed,
                             pct_abs_rel_error(lz, lz_true)))
                # Table 1 reproduces the paper's literal Eq. 6/7 estimator;
                # serving uses the anchored fix (core/mince.py)
                lz = jax.vmap(lambda qq, kk: mince_log_z(
                    v, qq, k, l, kk, weighting="paper"))(
                    q, keys)
                rows.append(("MINCE", k, l, seed,
                             pct_abs_rel_error(lz, lz_true)))
    elapsed = time.perf_counter() - t0

    # aggregate over seeds
    table = {}
    for name, k, l, seed, errs in rows:
        table.setdefault((name, k, l), []).append(np.mean(errs))
    out = []
    print("\n== Table 1 (paper: MIMPS k=1000,l=1000 -> 0.8; k=100,l=100 -> "
          "7.1; Uniform ~100; MINCE 2-5 orders worse) ==")
    print(f"{'method':8s} {'k':>5s} {'l':>5s} {'mu %':>10s} {'sigma':>8s}")
    for (name, k, l), vals in sorted(table.items()):
        mu = float(np.mean(vals))
        sig = float(np.std(vals) / np.sqrt(len(vals)))
        print(f"{name:8s} {k:5d} {l:5d} {mu:10.2f} {sig:8.2f}")
        out.append({"method": name, "k": k, "l": l, "mu": mu, "sigma": sig})
    n_calls = len(rows) * (1 if quick else n_queries)
    return out, elapsed * 1e6 / max(n_calls, 1)
