"""SS Roofline: derive the three roofline terms per (arch x shape x mesh)
from the dry-run artifacts (launch/dryrun.py must have run first).

Hardware model (TPU v5e target):
  peak    = 197 TFLOP/s bf16 per chip
  hbm_bw  = 819 GB/s per chip
  link_bw = 50 GB/s per chip (ICI)
"""
from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def _model_flops(rec):
    n = rec["active_param_count"]
    toks = rec["global_tokens"]
    kind = rec["step"]
    if "train" in kind:
        return 6.0 * n * toks
    if "prefill" in kind:
        return 2.0 * n * toks
    return 2.0 * n * toks          # decode: tokens == batch


ADVICE = {
    "compute": "reduce recompute (remat policy) / push useful-flops ratio up",
    "memory": "cut KV/activation traffic: smaller dtype, fuse the masked "
              "cache update, avoid layout copies",
    "collective": "reshard to remove per-layer activation all-gathers / "
                  "overlap collectives with compute",
}


def analyze_record(rec):
    chips = rec["n_chips"]
    fl = rec["flops_per_device"]
    by = rec["bytes_per_device"]
    coll = sum(rec["collective_bytes"].values())
    t_c = fl / PEAK
    t_m = by / HBM
    t_l = coll / LINK
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    mf = _model_flops(rec)
    useful = mf / max(fl * chips, 1.0)
    bound = max(terms.values())
    # roofline fraction: useful-model-flops time over the bound term
    ideal_t = mf / chips / PEAK
    frac = ideal_t / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec.get("output_mode", "exact"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dom, "model_flops": mf,
        "useful_flops_ratio": useful, "roofline_fraction": frac,
        "advice": ADVICE[dom],
    }


def run(art_dir="artifacts/dryrun", out_md="artifacts/roofline.md",
        quick=False):
    rows = []
    for path in sorted(glob.glob(f"{art_dir}/*/*.json")):
        rec = json.load(open(path))
        if "skipped" in rec:
            continue
        rows.append(analyze_record(rec))
    if not rows:
        print("no dry-run artifacts found — run launch/dryrun.py first")
        return [], 0.0
    hdr = (f"| {'arch':22s} | {'shape':11s} | mesh   | mode  | compute s | "
           f"memory s | coll s  | dominant   | useful | roofline |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']:22s} | {r['shape']:11s} | {r['mesh']:6s} | "
            f"{r['mode']:5s} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.2e} | {r['dominant']:10s} | "
            f"{r['useful_flops_ratio']:6.2f} | {r['roofline_fraction']:8.3f} |")
    os.makedirs(os.path.dirname(out_md), exist_ok=True)
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    print("\n== Roofline (per arch x shape x mesh; seconds per step) ==")
    print("\n".join(lines))
    return rows, 0.0


if __name__ == "__main__":
    run()
