"""Paper Fig. 1: CDF of per-class contributions to Z, sorted descending —
rare-word contexts concentrate (<1k neighbors for 80% of Z), frequent-word
contexts are flat (~80% of the vocabulary needed)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import make_embeddings


def neighbors_for_mass(v, q, mass=0.8):
    s = np.asarray(v @ q, np.float64)
    e = np.exp(s - s.max())
    e.sort()
    e = e[::-1]
    c = np.cumsum(e) / e.sum()
    return int(np.searchsorted(c, mass) + 1)


def run(n=20000, d=64, quick=False):
    if quick:
        n = 8000
    key = jax.random.PRNGKey(0)
    v = make_embeddings(key, n, d)
    t0 = time.perf_counter()
    # frequent words = low rank (large norm -> flat context distribution);
    # rare words = high rank (concentrated)
    freq_ranks = [0, 5, 50]
    rare_ranks = [n // 2, n - 100, n - 1]
    out = []
    print("\n== Fig. 1 (paper: rare ~<1k of 100k for 80% mass; frequent "
          "~80k of 100k) ==")
    for label, ranks in (("frequent", freq_ranks), ("rare", rare_ranks)):
        for r in ranks:
            k80 = neighbors_for_mass(v, v[r])
            frac = k80 / n
            print(f"  {label:9s} rank={r:6d}: {k80:6d} neighbors for 80% "
                  f"({100*frac:.1f}% of vocab)")
            out.append({"kind": label, "rank": r, "k80": k80, "frac": frac})
    elapsed = time.perf_counter() - t0
    freq_frac = np.mean([o["frac"] for o in out if o["kind"] == "frequent"])
    rare_frac = np.mean([o["frac"] for o in out if o["kind"] == "rare"])
    print(f"  => frequent words need {freq_frac/max(rare_frac,1e-9):.0f}x "
          "more neighbors (paper's NMIMPS-is-hopeless conclusion)")
    return out, elapsed * 1e6 / 6
