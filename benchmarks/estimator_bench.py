"""Per-estimator serving benchmark: exact vs mimps vs mince vs fmbe vs lsh
through the backend registry, tracked in ``BENCH_estimators.json``.

For a decode batch of Q queries against a V-row output embedding, each
registered method reports:

  * wall-clock of its jitted XLA decode (the honest number on this CPU
    container; PR 2's artifact recorded mimps *slower* than exact — 12.7ms
    vs 4.5ms — because the XLA path scored the full static probe capacity;
    the head_cap-trimmed decode now beats exact, and ``run.py --check``
    gates mimps < exact and mince <= 1.5x mimps from here on),
  * Pallas-vs-reference log-Ẑ parity (the kernel runs interpreted on CPU, so
    it is verified, not timed),
  * embedding floats per step from the backend's own SS5/SS8 accounting,
    asserted against the backend's ``floats_bound`` ceiling, and
  * mean relative error |1 - Ẑ/Z| vs the exact pass.

The decode batch models production serving (parallel sampling of one shared
context: probe sets overlap, dedup drives U -> n_probe); an uncorrelated
batch's U is reported alongside for honesty.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.configs.base import PartitionConfig
from repro.core import lsh as _lsh
from repro.core.backends import get_backend
from .common import (make_embeddings, shared_context_batch, time_fns,
                     unique_probed_blocks)

METHODS = ("exact", "mimps", "mince", "fmbe", "lsh")

# lsh knobs, sized at the bench's own scale. Two costs trade off: recall
# (the collision head catching every heavy row) wants more tables and
# bucket caps comfortably above the HOT-bucket load — the bench embeddings
# are clustered, so the query's own cluster lands in ONE bucket per table
# and a cap below the cluster size silently drops exactly the rows that
# matter — while wall-clock wants a tight candidate cap (head_cap) so the
# trimmed scoring matmul stays small. Tuned until the run.py --check gates
# hold with headroom: wall-clock < exact AND rel_err <= 0.1 at the bench
# seed (across-seed estimator variance is larger; DESIGN.md SS18).
_LSH_QUICK = dict(lsh_bits=7, lsh_tables=12, lsh_bucket_cap=256,
                  head_cap=1024, l=256, lsh_tail_beta=16.0)
_LSH_FULL = dict(lsh_bits=9, lsh_tables=12, lsh_bucket_cap=512,
                 head_cap=4096, l=1024, lsh_tail_beta=16.0)


def run(quick=True, out_path="BENCH_estimators.json"):
    n, d, br, p, l, q = ((8192, 128, 128, 8, 256, 32) if quick else
                         (65536, 256, 512, 16, 512, 64))
    p_feat, max_deg = (1024, 4) if quick else (4096, 8)
    key = jax.random.PRNGKey(0)
    v = make_embeddings(key, n, d)
    h = shared_context_batch(key, v, q)
    kd = jax.random.fold_in(key, 2)
    exact_lz = jax.nn.logsumexp((h @ v.T).astype(jnp.float32), -1)

    rows = {}
    u_shared = u_uncorr = None
    exact_floats = None
    jit_refs = {}
    for method in METHODS:
        # n_clusters=0 -> build_ivf auto-sizing, matching decode_bench so
        # the two artifacts report the same mimps traffic for one config
        over = ({} if method != "lsh" else
                (_LSH_QUICK if quick else _LSH_FULL))
        cfg = PartitionConfig(method=method, block_rows=br, n_probe=p,
                              l=over.get("l", l), n_clusters=0,
                              fmbe_features=p_feat, fmbe_max_degree=max_deg,
                              head_cap=over.get("head_cap", 0),
                              lsh_bits=over.get("lsh_bits", 8),
                              lsh_tables=over.get("lsh_tables", 8),
                              lsh_bucket_cap=over.get("lsh_bucket_cap", 0),
                              lsh_tail_beta=over.get("lsh_tail_beta", 8.0))
        bk = get_backend(method)
        state = bk.build(cfg, v, key)
        if u_shared is None and state.index is not None:
            u_shared = unique_probed_blocks(state.index, h, p)
            h_u = v[jax.random.choice(jax.random.fold_in(key, 3), n, (q,),
                                      replace=False)]
            u_uncorr = unique_probed_blocks(state.index, h_u, p)

        def ref_fn(hh, kk, bk=bk, state=state, cfg=cfg):
            return bk.decode(state, hh, kk, cfg, k=1, use_pallas=False)

        jit_refs[method] = jax.jit(ref_fn)
        out_ref = jit_refs[method](h, kd)
        out_pal = bk.decode(state, h, kd, cfg, k=1, use_pallas=True)
        parity = float(jnp.max(jnp.abs(out_pal.log_z - out_ref.log_z)))
        rel_err = float(jnp.mean(jnp.abs(1 - jnp.exp(out_ref.log_z
                                                     - exact_lz))))
        u = u_shared if bk.sublinear else None
        if method == "lsh" and state.lsh is not None:
            # measured dedup'd candidate rows (the lsh analogue of U):
            # ``unique_probed_blocks`` walks an IVF plan and does not apply
            plan = _lsh.lsh_plan(state.lsh, h, kd, cfg.l)
            u = int(plan.cand_live)
        floats = bk.embedding_floats(state, cfg, q, u=u)
        bound = bk.floats_bound(state, cfg, q)
        if method == "exact":
            exact_floats = floats
        rows[method] = {
            "embedding_floats_per_step": floats,
            "embedding_floats_per_token": floats / q,
            "floats_bound": bound,
            "fused_vs_ref_max_logz_diff": parity,
            "rel_err_vs_exact": rel_err,
            "sublinear": bk.sublinear,
            "bound_ok": bool(floats <= bound and parity <= 1e-4),
            "bytes_vs_exact": None if exact_floats is None
            else floats / exact_floats,
        }

    # one interleaved timing pass over every method: the run.py --check
    # invariants compare methods against each other, so per-method load
    # spikes must not decide the comparison
    times = time_fns([(jit_refs[m], (h, kd)) for m in METHODS], reps=25)
    for method, t_ref in zip(METHODS, times):
        rows[method]["us_per_step"] = t_ref * 1e6
        rows[method]["tokens_per_s"] = q / t_ref

    ok_all = all(r["bound_ok"] for r in rows.values())
    byte_sublinear = all(r["embedding_floats_per_step"] < exact_floats
                         for m, r in rows.items() if r["sublinear"])
    report = {
        "config": {"V": n, "d": d, "block_rows": br, "n_probe": p, "l": l,
                   "Q": q, "fmbe_features": p_feat,
                   "fmbe_max_degree": max_deg,
                   "unique_blocks_shared_ctx": u_shared,
                   "unique_blocks_uncorrelated": u_uncorr,
                   "backend": jax.default_backend()},
        "methods": rows,
        "bound": {"ok_all": bool(ok_all),
                  "byte_sublinear_all": bool(byte_sublinear),
                  "note": "per-method ceiling from backend.floats_bound; "
                          "sublinear methods must also touch fewer "
                          "embedding floats than exact on the shared-"
                          "context batch"},
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\n== Estimator bench (-> {os.path.abspath(out_path)}) ==")
    for m, r in rows.items():
        print(f"{m:6s}: {r['tokens_per_s']:10.0f} tok/s  "
              f"{r['embedding_floats_per_token']:12.0f} floats/tok  "
              f"rel_err {r['rel_err_vs_exact']:.3f}  "
              f"parity {r['fused_vs_ref_max_logz_diff']:.2e}  "
              f"bound_ok={r['bound_ok']}")
    us = rows["mimps"]["us_per_step"]
    return report, us


if __name__ == "__main__":
    run()
