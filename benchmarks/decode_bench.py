"""Decode-path benchmark: exact vs fused batched MIMPS, tracked in
``BENCH_decode.json`` from PR 1 onward.

Measures, for a decode batch of Q queries against a V-row output embedding:

  * tokens/s of the exact full-vocab path vs the sublinear MIMPS pipeline
    (both timed on their jitted XLA lowerings — on this CPU container the
    Pallas kernel runs in interpret mode, so wall-clock there is meaningless;
    the fused kernel is instead *verified* against the timed reference and
    its HBM traffic derived from the probe plan, which is exact: the kernel
    fetches precisely the deduplicated blocks + tail rows the plan names).
    The exact baseline is ``exact_topk_decode`` — ONE matmul feeding both
    the logsumexp and the argmax (the seed benchmarked a two-matmul exact,
    flattering MIMPS by ~2x).

  * HBM floats of embedding data per decode step / per token:
      exact : V*d + Q*d
      mimps : n_blocks*d (centroids) + U*br*d (dedup head) + l*d (tail rows)
              + Q*d (queries),  U = unique probed blocks across the batch
    checked against the acceptance bound (n_blocks + n_probe*block_rows + l)*d
    + Q*d. The decode batch models production serving: queries are
    perturbations of a shared context hidden state, so probe sets overlap and
    dedup drives U -> n_probe. An uncorrelated batch is reported alongside
    for honesty.

  * the autotuner's chosen tile config for the fused kernel (swept + cached
    by ``kernels.autotune``; on CPU the sweep times the interpreter, so the
    recorded config documents the machinery, not TPU-optimal tiles).

PR 3 acceptance (gated by ``benchmarks/run.py --check``): speedup_xla > 1 —
estimating Z must beat computing it in wall-clock, not just bytes.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import build_ivf, exact_topk_decode, mimps_decode
from .common import (make_embeddings, shared_context_batch, time_fns,
                     unique_probed_blocks)


def run(quick=True, out_path="BENCH_decode.json"):
    n, d, br, p, l, q = ((8192, 128, 128, 8, 256, 32) if quick else
                         (65536, 256, 512, 16, 512, 64))
    key = jax.random.PRNGKey(0)
    v = make_embeddings(key, n, d)
    index = build_ivf(key, v, block_rows=br)
    nb = index.n_blocks

    h = shared_context_batch(key, v, q)
    kd = jax.random.fold_in(key, 2)

    exact_fn = jax.jit(lambda h: exact_topk_decode(v, h, k=1,
                                                   use_pallas=False))
    mimps_ref = jax.jit(lambda h, k: mimps_decode(
        index, h, k, n_probe=p, l=l, k=1, use_pallas=False))
    # interleaved reps: a load spike on this container hits both contenders,
    # not just one — speedup_xla is a ratio and must not flip on noise
    t_exact, t_mimps = time_fns([(exact_fn, (h,)), (mimps_ref, (h, kd))],
                                reps=25)

    # fused Pallas pipeline (interpret on CPU): verify against the ref path
    out_pal = mimps_decode(index, h, kd, n_probe=p, l=l, k=1, use_pallas=True)
    out_ref = mimps_ref(h, kd)
    parity = float(jnp.max(jnp.abs(out_pal.log_z - out_ref.log_z)))
    exact_lz = exact_fn(h).log_z
    rel_err = float(jnp.mean(jnp.abs(1 - jnp.exp(out_pal.log_z - exact_lz))))

    # autotuner: sweep + cache the fused kernel's tile config for this shape
    # (the same plumbing Engine(autotune=True) uses)
    from repro.configs.base import PartitionConfig
    from repro.core.backends import get_backend
    bk = get_backend("mimps")
    pc = PartitionConfig(method="mimps", block_rows=br, n_probe=p, l=l,
                         n_clusters=0)
    from repro.core.backends import BackendState
    tuned = bk.tune(BackendState(w=v, index=index), pc, h, kd)

    # embedding-float accounting (per decode step of Q tokens)
    u_shared = unique_probed_blocks(index, h, p)
    h_uncorr = v[jax.random.choice(jax.random.fold_in(key, 3), n, (q,),
                                   replace=False)]
    u_uncorr = unique_probed_blocks(index, h_uncorr, p)
    exact_floats = n * d + q * d
    mimps_floats = nb * d + u_shared * br * d + l * d + q * d
    bound_floats = (nb + p * br + l) * d + q * d

    report = {
        "config": {"V": n, "d": d, "block_rows": br, "n_blocks": nb,
                   "n_probe": p, "l": l, "Q": q,
                   "backend": jax.default_backend()},
        "exact": {"us_per_step": t_exact * 1e6,
                  "tokens_per_s": q / t_exact,
                  "embedding_floats_per_step": exact_floats,
                  "embedding_floats_per_token": exact_floats / q},
        "mimps": {"us_per_step": t_mimps * 1e6,
                  "tokens_per_s": q / t_mimps,
                  "unique_blocks_shared_ctx": u_shared,
                  "unique_blocks_uncorrelated": u_uncorr,
                  "embedding_floats_per_step": mimps_floats,
                  "embedding_floats_per_token": mimps_floats / q,
                  "fused_vs_ref_max_logz_diff": parity,
                  "rel_err_vs_exact": rel_err},
        "bound": {"floats_per_step": bound_floats,
                  "formula": "(n_blocks + n_probe*block_rows + l)*d + Q*d",
                  "ok": mimps_floats <= bound_floats and parity <= 1e-4},
        "autotune": {"ivf_decode": tuned,
                     "note": "kernels.autotune sweep (cached by shape/dtype/"
                             "backend); CPU times the interpreter"},
        "speedup_xla": t_exact / t_mimps,
        "bytes_reduction": exact_floats / mimps_floats,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\n== Decode bench (-> {os.path.abspath(out_path)}) ==")
    print(f"exact : {q / t_exact:10.0f} tok/s  "
          f"{exact_floats / q:12.0f} floats/tok")
    print(f"mimps : {q / t_mimps:10.0f} tok/s  "
          f"{mimps_floats / q:12.0f} floats/tok  "
          f"(U={u_shared} shared / {u_uncorr} uncorrelated, "
          f"parity {parity:.2e}, bound_ok={report['bound']['ok']}, "
          f"speedup_xla={t_exact / t_mimps:.2f})")
    us = t_mimps * 1e6
    return report, us


if __name__ == "__main__":
    run()
