"""Decode-path benchmark: exact vs fused batched MIMPS, tracked in
``BENCH_decode.json`` from this PR onward.

Measures, for a decode batch of Q queries against a V-row output embedding:

  * tokens/s of the exact full-vocab path vs the sublinear MIMPS pipeline
    (both timed on their jitted XLA lowerings — on this CPU container the
    Pallas kernel runs in interpret mode, so wall-clock there is meaningless;
    the fused kernel is instead *verified* against the timed reference and
    its HBM traffic derived from the probe plan, which is exact: the kernel
    fetches precisely the deduplicated blocks + tail rows the plan names).

  * HBM floats of embedding data per decode step / per token:
      exact : V*d + Q*d
      mimps : n_blocks*d (centroids) + U*br*d (dedup head) + l*d (tail rows)
              + Q*d (queries),  U = unique probed blocks across the batch
    checked against the acceptance bound (n_blocks + n_probe*br + l)*d + Q*d.
    The decode batch models production serving: queries are perturbations of
    a shared context hidden state, so probe sets overlap and dedup drives
    U -> n_probe. An uncorrelated batch is reported alongside for honesty.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import build_ivf, mimps_decode
from .common import (make_embeddings, shared_context_batch, time_fn,
                     unique_probed_blocks)


def run(quick=True, out_path="BENCH_decode.json"):
    n, d, br, p, l, q = ((8192, 128, 128, 8, 256, 32) if quick else
                         (65536, 256, 512, 16, 512, 64))
    key = jax.random.PRNGKey(0)
    v = make_embeddings(key, n, d)
    index = build_ivf(key, v, block_rows=br)
    nb = index.n_blocks

    h = shared_context_batch(key, v, q)
    kd = jax.random.fold_in(key, 2)

    exact_fn = jax.jit(lambda h: (jax.nn.logsumexp(h @ v.T, -1),
                                  jnp.argmax(h @ v.T, -1)))
    mimps_ref = jax.jit(lambda h, k: mimps_decode(
        index, h, k, n_probe=p, l=l, k=1, use_pallas=False))
    t_exact = time_fn(exact_fn, h)
    t_mimps = time_fn(mimps_ref, h, kd)

    # fused Pallas pipeline (interpret on CPU): verify against the ref path
    out_pal = mimps_decode(index, h, kd, n_probe=p, l=l, k=1, use_pallas=True)
    out_ref = mimps_ref(h, kd)
    parity = float(jnp.max(jnp.abs(out_pal.log_z - out_ref.log_z)))
    exact_lz = exact_fn(h)[0]
    rel_err = float(jnp.mean(jnp.abs(1 - jnp.exp(out_pal.log_z - exact_lz))))

    # embedding-float accounting (per decode step of Q tokens)
    u_shared = unique_probed_blocks(index, h, p)
    h_uncorr = v[jax.random.choice(jax.random.fold_in(key, 3), n, (q,),
                                   replace=False)]
    u_uncorr = unique_probed_blocks(index, h_uncorr, p)
    exact_floats = n * d + q * d
    mimps_floats = nb * d + u_shared * br * d + l * d + q * d
    bound_floats = (nb + p * br + l) * d + q * d

    report = {
        "config": {"V": n, "d": d, "block_rows": br, "n_blocks": nb,
                   "n_probe": p, "l": l, "Q": q,
                   "backend": jax.default_backend()},
        "exact": {"us_per_step": t_exact * 1e6,
                  "tokens_per_s": q / t_exact,
                  "embedding_floats_per_step": exact_floats,
                  "embedding_floats_per_token": exact_floats / q},
        "mimps": {"us_per_step": t_mimps * 1e6,
                  "tokens_per_s": q / t_mimps,
                  "unique_blocks_shared_ctx": u_shared,
                  "unique_blocks_uncorrelated": u_uncorr,
                  "embedding_floats_per_step": mimps_floats,
                  "embedding_floats_per_token": mimps_floats / q,
                  "fused_vs_ref_max_logz_diff": parity,
                  "rel_err_vs_exact": rel_err},
        "bound": {"floats_per_step": bound_floats,
                  "formula": "(n_blocks + n_probe*block_rows + l)*d + Q*d",
                  "ok": mimps_floats <= bound_floats and parity <= 1e-4},
        "speedup_xla": t_exact / t_mimps,
        "bytes_reduction": exact_floats / mimps_floats,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\n== Decode bench (-> {os.path.abspath(out_path)}) ==")
    print(f"exact : {q / t_exact:10.0f} tok/s  "
          f"{exact_floats / q:12.0f} floats/tok")
    print(f"mimps : {q / t_mimps:10.0f} tok/s  "
          f"{mimps_floats / q:12.0f} floats/tok  "
          f"(U={u_shared} shared / {u_uncorr} uncorrelated, "
          f"parity {parity:.2e}, bound_ok={report['bound']['ok']})")
    us = t_mimps * 1e6
    return report, us


if __name__ == "__main__":
    run()
