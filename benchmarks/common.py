"""Shared benchmark substrate: synthetic word2vec-like embeddings + queries.

The container is offline (no GoogleNews vectors / PTB), so we synthesize
class-vector sets with the two statistics that drive the paper's phenomena:
  * cluster structure (words live near topic centroids),
  * Zipf-rank-correlated norms (frequent words -> flatter distributions,
    the Fig. 1 effect).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def make_embeddings(key, n: int, d: int, n_centers: int = 64,
                    spread: float = 0.6, score_scale: float = 0.35):
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.normal(k1, (n_centers, d))
    asg = jax.random.randint(k2, (n,), 0, n_centers)
    v = centers[asg] + spread * jax.random.normal(k3, (n, d))
    v = v / jnp.linalg.norm(v, axis=1, keepdims=True)
    # Frequent (low-rank) words have SMALL norms (they co-occur with
    # everything, like "The") -> their queries induce flat distributions;
    # rare words have large, specialized norms -> concentrated distributions.
    # This is the word2vec norm/distinctiveness correlation behind Fig. 1.
    rank = jnp.arange(n) / n
    norm = 0.35 + 1.8 * jnp.sqrt(rank)
    return v * norm[:, None] * jnp.sqrt(d) * score_scale


def make_queries(key, v, n_queries: int, noise_rel: float = 0.0):
    """Queries = class vectors (+ optional relative-norm gaussian noise),
    mirroring SS5.1's construction."""
    kq, kn = jax.random.split(key)
    idx = jax.random.choice(kq, v.shape[0], (n_queries,), replace=False)
    q = v[idx]
    if noise_rel > 0:
        noise = jax.random.normal(kn, q.shape)
        noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)
        q = q + noise * noise_rel * jnp.linalg.norm(q, axis=1, keepdims=True)
    return q, idx


def pct_abs_rel_error(log_z_hat, log_z_true):
    """The paper's mu = 100 |Z_hat - Z| / Z, computed stably in log space."""
    return 100.0 * np.abs(1.0 - np.exp(np.asarray(log_z_hat, np.float64)
                                       - np.asarray(log_z_true, np.float64)))


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
