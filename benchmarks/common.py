"""Shared benchmark substrate: synthetic word2vec-like embeddings + queries.

The container is offline (no GoogleNews vectors / PTB), so we synthesize
class-vector sets with the two statistics that drive the paper's phenomena:
  * cluster structure (words live near topic centroids),
  * Zipf-rank-correlated norms (frequent words -> flatter distributions,
    the Fig. 1 effect).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def make_embeddings(key, n: int, d: int, n_centers: int = 64,
                    spread: float = 0.6, score_scale: float = 0.35):
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.normal(k1, (n_centers, d))
    asg = jax.random.randint(k2, (n,), 0, n_centers)
    v = centers[asg] + spread * jax.random.normal(k3, (n, d))
    v = v / jnp.linalg.norm(v, axis=1, keepdims=True)
    # Frequent (low-rank) words have SMALL norms (they co-occur with
    # everything, like "The") -> their queries induce flat distributions;
    # rare words have large, specialized norms -> concentrated distributions.
    # This is the word2vec norm/distinctiveness correlation behind Fig. 1.
    rank = jnp.arange(n) / n
    norm = 0.35 + 1.8 * jnp.sqrt(rank)
    return v * norm[:, None] * jnp.sqrt(d) * score_scale


def make_queries(key, v, n_queries: int, noise_rel: float = 0.0):
    """Queries = class vectors (+ optional relative-norm gaussian noise),
    mirroring SS5.1's construction."""
    kq, kn = jax.random.split(key)
    idx = jax.random.choice(kq, v.shape[0], (n_queries,), replace=False)
    q = v[idx]
    if noise_rel > 0:
        noise = jax.random.normal(kn, q.shape)
        noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)
        q = q + noise * noise_rel * jnp.linalg.norm(q, axis=1, keepdims=True)
    return q, idx


def pct_abs_rel_error(log_z_hat, log_z_true):
    """The paper's mu = 100 |Z_hat - Z| / Z, computed stably in log space."""
    return 100.0 * np.abs(1.0 - np.exp(np.asarray(log_z_hat, np.float64)
                                       - np.asarray(log_z_true, np.float64)))


def time_fn(fn, *args, reps=10):
    """Best-of-reps wall-clock of a jitted call (one warm-up; per-rep block).

    Minimum, not mean: on a shared/noisy container the mean measures the
    neighbors, the minimum measures the code — and the CI regression gate
    (benchmarks/run.py --check) compares wall-clock across runs, so the
    estimator needs to be stable against load spikes.
    """
    return time_fns([(fn, args)], reps=reps)[0]


def time_fns(fns_with_args, reps=10):
    """Best-of-reps for SEVERAL jitted calls, reps interleaved round-robin.

    The decode benches compare methods against each other (speedup_xla,
    mince-vs-mimps); timing them back-to-back lets a load spike land
    entirely on one contender and flip the comparison. Round-robin spreads
    any spike across all of them. Returns [best_seconds, ...] in input
    order.
    """
    for fn, args in fns_with_args:
        jax.block_until_ready(fn(*args))              # compile + warm
    best = [float("inf")] * len(fns_with_args)
    for _ in range(reps):
        for i, (fn, args) in enumerate(fns_with_args):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def shared_context_batch(key, v, q: int, noise_rel: float = 0.01):
    """Decode batch modeling production serving: parallel sampling /
    best-of-N of ONE prompt — per-stream hidden states are small
    perturbations of a shared context vector, so probe sets overlap and
    union dedup drives U -> n_probe."""
    base = v[1234]
    d = v.shape[1]
    noise = jax.random.normal(jax.random.fold_in(key, 1), (q, d))
    return base[None, :] + noise_rel * noise * jnp.linalg.norm(base) \
        / jnp.sqrt(d)


def unique_probed_blocks(index, h, n_probe: int) -> int:
    """Measured deduplicated probe count U for a batch (plan_heads union)."""
    from repro.core import probe_batch
    from repro.core.decode import plan_heads
    bids = probe_batch(index, h, n_probe)
    _, _, n_unique = plan_heads(bids, min(h.shape[0] * n_probe,
                                          index.n_blocks))
    return int(n_unique)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
