"""Paper Table 4 / SS5.2: end-to-end LBL language model trained with NCE
(Z clamped to 1), then partition-function estimation on held-out contexts.

AbsE-MIPS  : sum |Z_hat - Z| with MIMPS over the block-IVF index (our
             TPU-native FLANN k-means-tree analogue)
AbsE-NCE   : sum |1 - Z| (the self-normalization heuristic)
%Better    : how often MIMPS beats the Z=1 heuristic
Speedup    : brute-force FLOPs / MIMPS FLOPs (+ measured wall-clock ratio)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_ivf, mimps_ivf, exact_log_z
from repro.data import SyntheticCorpus, zipf_probs
from repro.models import lbl


def train_lbl(key, vocab=10000, d=100, ctx=4, steps=300, batch=256,
              n_noise=32, lr=0.05):
    corpus = SyntheticCorpus(vocab=vocab, seed=1)
    probs = jnp.asarray(zipf_probs(vocab))
    log_probs = jnp.log(probs)
    params = lbl.init_lbl(key, vocab, d, ctx)

    @jax.jit
    def step(params, toks, knoise):
        ctx_t = toks[:, :ctx]
        tgt = toks[:, ctx]
        noise = jax.random.categorical(knoise, log_probs[None, :],
                                       shape=(toks.shape[0], n_noise))
        lnp = (log_probs[tgt], log_probs[noise])

        def loss_fn(p):
            return lbl.nce_loss(p, ctx_t, tgt, noise, lnp, n_noise)
        loss, g = jax.value_and_grad(loss_fn)(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                          for x in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, 1.0 / (gn + 1e-9))
        params = jax.tree.map(lambda p_, g_: p_ - lr * scale * g_, params, g)
        return params, loss

    for i in range(steps):
        toks = jnp.asarray(corpus.batch(i, batch, ctx))
        params, loss = step(params, toks,
                            jax.random.fold_in(key, 10_000 + i))
    return params, corpus, float(loss)


def run(quick=False):
    vocab, steps, n_test = (4000, 150, 200) if quick else (10000, 300, 500)
    key = jax.random.PRNGKey(7)
    t0 = time.perf_counter()
    params, corpus, final_loss = train_lbl(key, vocab=vocab, steps=steps)
    train_s = time.perf_counter() - t0

    v = lbl.class_vectors(params)                       # (V, d+1)
    idx = build_ivf(jax.random.fold_in(key, 1), v, block_rows=128)

    # held-out contexts
    toks = jnp.asarray(corpus.batch(999_999, n_test, 4))
    q = lbl.query_vector(params, toks[:, :4])           # (B, d+1)
    lz_true = jax.vmap(lambda qq: exact_log_z(v, qq))(q)
    z_true = np.exp(np.asarray(lz_true, np.float64))

    results = {}
    for (n_probe, l) in [(4, 10), (4, 100), (8, 100), (16, 100)]:
        keys = jax.random.split(jax.random.fold_in(key, 2), n_test)
        est = jax.jit(jax.vmap(
            lambda qq, kk: mimps_ivf(idx, qq, n_probe, l, kk).log_z))
        lz = est(q, keys)
        jax.block_until_ready(lz)
        t1 = time.perf_counter()
        lz = est(q, keys)
        jax.block_until_ready(lz)
        t_mips = time.perf_counter() - t1
        z_hat = np.exp(np.asarray(lz, np.float64))
        abse_mips = float(np.sum(np.abs(z_hat - z_true)))
        abse_nce = float(np.sum(np.abs(1.0 - z_true)))
        better = float(np.mean(np.abs(z_hat - z_true)
                               < np.abs(1.0 - z_true)))
        flops_brute = v.shape[0] * v.shape[1]
        k_eff = n_probe * idx.block_rows
        flops_mips = (idx.n_blocks + k_eff + l) * v.shape[1]
        results[(n_probe, l)] = dict(
            abse_mips=abse_mips, abse_nce=abse_nce, better=100 * better,
            speedup_flops=flops_brute / flops_mips, t_us=t_mips * 1e6 / n_test)

    print("\n== Table 4 (paper: MIMPS k,l~100 beats Z=1 heuristic 70.5% "
          f"at ~10x speedup) ==   [LBL NCE train loss {final_loss:.3f}, "
          f"{train_s:.0f}s]")
    print(f"{'probe':>5s} {'l':>4s} {'AbsE-MIPS':>10s} {'AbsE-NCE':>9s} "
          f"{'%Better':>8s} {'Speedup':>8s} {'us/query':>9s}")
    out = []
    for (p, l), r in results.items():
        print(f"{p:5d} {l:4d} {r['abse_mips']:10.1f} {r['abse_nce']:9.1f} "
              f"{r['better']:8.1f} {r['speedup_flops']:8.1f} "
              f"{r['t_us']:9.1f}")
        out.append({"n_probe": p, "l": l, **r})
    return out, train_s * 1e6 / steps
