"""Benchmark driver — one section per paper table/figure + kernel benches +
the roofline reader. Prints ``name,us_per_call,derived`` CSV lines at the end.

  PYTHONPATH=src python -m benchmarks.run [--full]

Regression gate (CI):

  PYTHONPATH=src python -m benchmarks.run --check

compares the freshly-written BENCH_decode.json / BENCH_estimators.json /
BENCH_serving.json / BENCH_train.json against the committed
``benchmarks/baseline.json`` and fails on a >25% wall-clock regression
(us_per_step up or tokens_per_s down) for any tracked method, AND enforces
the acceptance invariants: speedup_xla > 1, mimps faster than exact, mince
within 1.5x of mimps (PR 3); continuous batching beats sequential
generate() on goodput, steady-state slot occupancy > 0.5, batched-vs-solo
token parity, zero recompiles after warmup (PR 4); estimator-backed
training writes < 0.35x the embedding-grad floats of fused_ce with grad
cosine >= 0.99, final loss within 5%, and zero recompiles across index
refreshes (PR 5); under 2x sustained overload the server sheds (0 <
shed_rate < 1), keeps a finite p95, engages the degradation ladder
(degraded_token_frac > 0), respects the queue bound, and never recompiles
(PR 6); the mesh-sharded scheduler step keeps token parity and zero
recompiles at every (data, model) mesh shape with tokens-per-step goodput
monotone along the 1/2/4/8-device chain (PR 7); estimator-speculative
decoding beats the non-speculative scheduler on goodput for the
shared-prefix trace with 0 < acceptance <= 1, and the warm prefix cache
saves replay steps (fewer virtual steps, saved_replay_steps > 0) — both
with token parity and zero recompiles (PR 8); the observability layer
fully enabled costs < 5% goodput with bit-identical tokens and zero
recompiles, the latency rows (p50/p95/p99, device/host step split,
per-tier cumulative histograms from the device metric state) are finite
and monotone, and the overload run's harvested per-tier token counts
reconcile exactly with the host report (PR 9). Failure messages print the
offending key, the measured value, and the bound. Refresh the baseline
after a *deliberate* perf change with:

  PYTHONPATH=src python -m benchmarks.run --update-baseline
"""
from __future__ import annotations

import argparse
import json
import math
import os
import platform
import sys

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")
TOL = 1.25   # >25% regression fails


def _machine() -> dict:
    """Host fingerprint stored with the baseline: absolute wall-clock only
    compares like against like (a slower CI runner generation is not a code
    regression); the ratio invariants below are enforced everywhere."""
    model = platform.processor() or ""
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("model name"):
                    model = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return {"machine": platform.machine(), "cpu_count": os.cpu_count(),
            "cpu_model": model}


def _load(path):
    with open(path) as f:
        return json.load(f)


def _snapshot():
    """The tracked perf surface of the four serving/training artifacts."""
    dec = _load("BENCH_decode.json")
    est = _load("BENCH_estimators.json")
    srv = _load("BENCH_serving.json")
    trn = _load("BENCH_train.json")
    snap = {"decode": {m: {"us_per_step": dec[m]["us_per_step"],
                           "tokens_per_s": dec[m]["tokens_per_s"]}
                       for m in ("exact", "mimps")},
            "decode_speedup_xla": dec["speedup_xla"],
            "estimators": {m: {"us_per_step": r["us_per_step"],
                               "tokens_per_s": r["tokens_per_s"]}
                           for m, r in est["methods"].items()},
            "serving": {"goodput_tok_s": srv["goodput_tok_s"],
                        "p95_token_ms": srv["p95_token_ms"]},
            "serving_scaling": {
                f"{r['data']}x{r['model']}": {
                    "tok_per_step": r["tok_per_step"],
                    "goodput_tok_s": r["goodput_tok_s"]}
                for r in srv.get("scaling", {}).get("rows", [])},
            "serving_spec": {
                d: {"goodput_tok_s": r["goodput_tok_s"],
                    "tok_per_step": r["tok_per_step"],
                    "acceptance": r["acceptance"]}
                for d, r in srv.get("spec", {}).get("drafts", {}).items()},
            "serving_prefix": {
                mode: srv["prefix_cache"][mode]["goodput_tok_s"]
                for mode in ("off", "on")} if "prefix_cache" in srv else {},
            "train": {m: {"tokens_per_s": r["tokens_per_s"],
                          "us_per_step": r["us_per_step"]}
                      for m, r in trn["methods"].items()}}
    return snap, dec, est, srv, trn


def update_baseline() -> None:
    snap, *_ = _snapshot()
    snap["host"] = _machine()
    with open(BASELINE_PATH, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"baseline written -> {BASELINE_PATH}")


def _gate_msg(key: str, measured, bound: str, why: str = "") -> str:
    """Uniform --check failure line: the offending artifact key, the value
    measured this run, and the bound it broke — so a red CI log names the
    exact number to go look at without re-running the bench."""
    m = f"{measured:.4g}" if isinstance(measured, float) else f"{measured}"
    return f"{key}: measured {m}, bound {bound}" + \
        (f" — {why}" if why else "")


def check() -> int:
    """Compare fresh artifacts against the committed baseline. Returns the
    number of failures (0 = green)."""
    snap, dec, est, srv, trn = _snapshot()
    base = _load(BASELINE_PATH)
    failures = []
    same_host = base.get("host") == _machine()
    if not same_host:
        print("note: baseline was recorded on a different host "
              f"({base.get('host')} vs {_machine()}); absolute wall-clock "
              "comparisons skipped, ratio invariants still enforced")

    def cmp_section(name, cur, ref):
        for method, row in ref.items():
            if method not in cur:
                failures.append(f"{name}.{method}: missing from artifact")
                continue
            us, us0 = cur[method]["us_per_step"], row["us_per_step"]
            tps, tps0 = cur[method]["tokens_per_s"], row["tokens_per_s"]
            if us > us0 * TOL:
                failures.append(_gate_msg(
                    f"{name}.{method}.us_per_step", us,
                    f"<= {TOL:.2f}x baseline {us0:.0f}"))
            if tps < tps0 / TOL:
                failures.append(_gate_msg(
                    f"{name}.{method}.tokens_per_s", tps,
                    f">= baseline {tps0:.0f} / {TOL:.2f}"))

    if same_host:
        cmp_section("decode", snap["decode"], base.get("decode", {}))
        cmp_section("estimators", snap["estimators"],
                    base.get("estimators", {}))
        cmp_section("train", snap["train"], base.get("train", {}))
        ref_srv = base.get("serving")
        if ref_srv:
            # goodput only: p95 is stored for trend-watching but is a
            # small-sample tail statistic — on a shared container it
            # measures the neighbors, not the code
            cur = snap["serving"]
            if cur["goodput_tok_s"] < ref_srv["goodput_tok_s"] / TOL:
                failures.append(_gate_msg(
                    "serving.goodput_tok_s", cur["goodput_tok_s"],
                    f">= baseline {ref_srv['goodput_tok_s']:.0f} / "
                    f"{TOL:.2f}"))

    # wall-clock acceptance invariants (machine-relative, so they are stable
    # across runner generations in a way absolute us_per_step is not)
    if dec["speedup_xla"] <= 1.0:
        failures.append(
            f"decode: speedup_xla {dec['speedup_xla']:.2f} <= 1.0 — the "
            f"sublinear estimator must beat the exact pass in wall-clock")
    em = est["methods"]
    if em["mimps"]["us_per_step"] >= em["exact"]["us_per_step"]:
        failures.append(
            f"estimators: mimps {em['mimps']['us_per_step']:.0f}us >= "
            f"exact {em['exact']['us_per_step']:.0f}us")
    if em["mince"]["us_per_step"] > 1.5 * em["mimps"]["us_per_step"]:
        failures.append(
            f"estimators: mince {em['mince']['us_per_step']:.0f}us > 1.5x "
            f"mimps {em['mimps']['us_per_step']:.0f}us")
    for m, cap in (("mimps", 0.5), ("mince", 1.0), ("fmbe", 0.5)):
        if em[m]["rel_err_vs_exact"] >= cap:
            failures.append(
                f"estimators: {m} rel_err {em[m]['rel_err_vs_exact']:.3g} "
                f">= {cap} (accuracy regression)")
    if not est["bound"]["ok_all"]:
        failures.append(
            "estimators: bound_ok_all false — some method exceeded its "
            "floats_bound ceiling or broke Pallas/XLA parity")
    if not est["bound"]["byte_sublinear_all"]:
        failures.append(
            "estimators: byte_sublinear_all false — a sublinear method "
            "touched more embedding floats than exact")

    # lsh acceptance invariants (PR 10): the SimHash collision backend must
    # beat the exact pass in wall-clock at bench scale with rel_err <= 0.1
    # at the bench seed (both measured on the same interleaved timing pass),
    # and its O(R)-row index maintenance (update_rows) must cost strictly
    # less than a full IVF re-cluster at equal embedding churn.
    if "lsh" not in em:
        failures.append("estimators: lsh method missing from artifact")
    else:
        if em["lsh"]["us_per_step"] >= em["exact"]["us_per_step"]:
            failures.append(
                f"estimators: lsh {em['lsh']['us_per_step']:.0f}us >= "
                f"exact {em['exact']['us_per_step']:.0f}us — the collision "
                f"probe must beat the dense pass in wall-clock")
        if em["lsh"]["rel_err_vs_exact"] > 0.1:
            failures.append(
                f"estimators: lsh rel_err "
                f"{em['lsh']['rel_err_vs_exact']:.3g} > 0.1 at the bench "
                f"seed (collision-head recall regression)")
    rc = trn.get("refresh_cost")
    if not rc:
        failures.append("train: refresh_cost section missing from artifact")
    elif rc["lsh_update_us"] >= rc["ivf_refresh_us"]:
        failures.append(
            f"train: lsh update_rows {rc['lsh_update_us']:.0f}us >= IVF "
            f"refresh {rc['ivf_refresh_us']:.0f}us at "
            f"{rc['rows_updated']} churned rows — the O(R) splice lost to "
            f"the full re-cluster")
    lsh_tm = trn["methods"].get("lsh_ce")
    if not lsh_tm:
        failures.append("train: lsh_ce run missing from artifact")
    else:
        lrf = lsh_tm["refresh"]
        if lrf["step_retraces"] != 1 or lrf["refresh_retraces"] != 1:
            failures.append(
                f"train: lsh_ce {lrf['step_retraces'] - 1} step + "
                f"{lrf['refresh_retraces'] - 1} refresh recompiles across "
                f"index refreshes")
        if lrf["count"] < 1:
            failures.append(
                "train: the bench never exercised an lsh index refresh")

    # training acceptance invariants (exact ratios, PR 5): the estimator in
    # the gradient must write sublinear embedding-grad floats, match the
    # full-CE gradient direction, learn what fused_ce learns, and refresh
    # the index without a single recompile.
    tm = trn["methods"]["mimps_ce"]
    if trn["grad_float_ratio"] >= 0.35:
        failures.append(
            f"train: embedding-grad float ratio "
            f"{trn['grad_float_ratio']:.3f} >= 0.35 vs fused_ce — the "
            f"sparse backward is not sublinear at bench scale")
    if tm["grad_cosine_vs_full"] < 0.99:
        failures.append(
            f"train: mimps_ce grad cosine {tm['grad_cosine_vs_full']:.4f} "
            f"< 0.99 vs full-CE embedding gradient")
    if not (0.95 <= trn["loss_ratio_vs_fused"] <= 1.05):
        failures.append(
            f"train: mimps_ce final loss is {trn['loss_ratio_vs_fused']:.3f}"
            f"x fused_ce (must be within 5% after the step budget)")
    rf = tm["refresh"]
    if rf["step_retraces"] != 1 or rf["refresh_retraces"] != 1:
        failures.append(
            f"train: {rf['step_retraces'] - 1} step + "
            f"{rf['refresh_retraces'] - 1} refresh recompiles across index "
            f"refreshes (the static-capacity repack must reuse one "
            f"executable)")
    if rf["count"] < 1:
        failures.append("train: the bench never exercised an index refresh")

    # serving acceptance invariants (machine-relative / exact, PR 4):
    # continuous batching must beat sequential generate() on goodput at
    # >= 8 concurrent mixed-length requests, with saturated slots, ZERO
    # recompiles after warmup, and bit-identical batched-vs-solo tokens.
    if srv["speedup_vs_sequential"] <= 1.0:
        failures.append(
            f"serving: continuous goodput {srv['goodput_tok_s']:.0f} tok/s "
            f"<= sequential {srv['sequential_goodput_tok_s']:.0f} "
            f"(speedup {srv['speedup_vs_sequential']:.2f}x)")
    if srv["peak_concurrency"] < 8:
        failures.append(
            f"serving: peak concurrency {srv['peak_concurrency']} < 8 — "
            f"the workload never filled the slot table")
    if srv["occupancy_steady"] <= 0.5:
        failures.append(
            f"serving: steady-state occupancy {srv['occupancy_steady']:.2f}"
            f" <= 0.5 (admission is starving the slot table)")
    if not srv["token_parity_vs_solo"]:
        failures.append(
            "serving: batched tokens differ from solo generate() — the "
            "slot table broke per-request sampling")
    if srv["recompiles_after_warmup"] != 0:
        failures.append(
            f"serving: {srv['recompiles_after_warmup']} recompiles after "
            f"warmup (the mixed step must serve every admission/replay/"
            f"decode mix with one executable)")

    # latency rows (obs satellite): the host tail percentiles and the
    # device/host step-time split must be finite positives, and the
    # device-harvested per-tier histogram rows — emitted cumulative — must
    # be monotone non-decreasing with every tier that served tokens present.
    lat = srv.get("latency")
    if not lat:
        failures.append("serving: latency section missing from artifact")
    else:
        for key in ("p50_token_ms", "p95_token_ms", "p99_token_ms",
                    "step_device_ms_mean", "step_host_ms_mean"):
            v = lat.get(key)
            if v is None or not math.isfinite(v) or v <= 0:
                failures.append(_gate_msg(
                    f"serving.latency.{key}", v, "finite and > 0"))
        p50, p95, p99 = (lat.get("p50_token_ms", 0),
                         lat.get("p95_token_ms", 0),
                         lat.get("p99_token_ms", 0))
        if not p50 <= p95 <= p99:
            failures.append(_gate_msg(
                "serving.latency.p50<=p95<=p99", (p50, p95, p99),
                "ordered percentiles"))
        hist = lat.get("per_tier_cumulative", {})
        if not hist:
            failures.append(
                "serving.latency.per_tier_cumulative: empty — the device "
                "histogram harvested no steps")
        for tier, row in hist.items():
            if any(b < a for a, b in zip(row, row[1:])):
                failures.append(_gate_msg(
                    f"serving.latency.per_tier_cumulative[{tier}]", row,
                    "monotone non-decreasing cumulative buckets"))
            if len(row) != len(lat.get("edges_ms", [])) + 1:
                failures.append(_gate_msg(
                    f"serving.latency.per_tier_cumulative[{tier}].len",
                    len(row), f"{len(lat.get('edges_ms', []))} edges + "
                    f"overflow bucket"))

    # observability overhead (obs tentpole acceptance): obs fully enabled
    # must keep tokens bit-identical to obs-off, trace nothing new, and
    # cost < 5% goodput. The ratio is measured within one process on one
    # host (interleaved best-of-5), so it is machine-relative and enforced
    # unconditionally.
    oo = srv.get("obs_overhead")
    if not oo:
        failures.append("serving: obs_overhead section missing from "
                        "artifact")
    else:
        if oo["goodput_ratio_on_vs_off"] < 0.95:
            failures.append(_gate_msg(
                "serving.obs_overhead.goodput_ratio_on_vs_off",
                oo["goodput_ratio_on_vs_off"], ">= 0.95",
                "the observability layer costs more than 5% goodput"))
        if not oo["token_parity_on_vs_off"]:
            failures.append(
                "serving.obs_overhead: tokens differ with observability "
                "on — instrumentation must not perturb sampling")
        if oo["recompiles_after_warmup"] != 0:
            failures.append(_gate_msg(
                "serving.obs_overhead.recompiles_after_warmup",
                oo["recompiles_after_warmup"], "== 0",
                "toggling obs changed an executable"))

    # overload acceptance invariants (exact, PR 6): at 2x sustained demand
    # through a bounded queue + degradation ladder, the server must shed
    # (not hang), keep serving the admitted work with a finite tail, walk
    # the ladder deterministically, respect the queue bound, and do all of
    # it without a single recompile.
    ov = srv.get("overload")
    if not ov:
        failures.append("serving: overload scenario missing from artifact")
    else:
        if not ov["shed_rate"] > 0.0:
            failures.append(
                "serving.overload: shed_rate == 0 at 2x demand with a "
                "bounded queue — backpressure never engaged")
        if not ov["shed_rate"] < 1.0:
            failures.append(
                "serving.overload: shed_rate == 1 — the server shed "
                "everything instead of serving what fit")
        if not math.isfinite(ov["p95_under_overload"]) or \
                ov["p95_under_overload"] <= 0:
            failures.append(
                f"serving.overload: p95_under_overload "
                f"{ov['p95_under_overload']} is not a finite positive "
                f"latency — admitted requests starved under overload")
        if not ov["degraded_token_frac"] > 0.0:
            failures.append(
                "serving.overload: degraded_token_frac == 0 — sustained "
                "queue pressure never engaged the estimator-tier ladder")
        if ov["queue_depth_peak"] > ov["max_queue"]:
            failures.append(
                f"serving.overload: queue_depth_peak "
                f"{ov['queue_depth_peak']} > max_queue {ov['max_queue']} "
                f"(the bounded queue leaked)")
        if ov["recompiles_after_warmup"] != 0:
            failures.append(
                f"serving.overload: {ov['recompiles_after_warmup']} "
                f"recompiles under overload (tier switches must reuse the "
                f"per-tier executables compiled at warmup)")
        oobs = ov.get("obs")
        if not oobs:
            failures.append("serving.overload: obs section missing — the "
                            "overload run must ride fully instrumented")
        else:
            if not oobs["tokens_reconciled"]:
                failures.append(_gate_msg(
                    "serving.overload.obs.tokens_by_tier_harvested",
                    oobs["tokens_by_tier_harvested"],
                    f"== ServerReport.tokens_by_tier "
                    f"{ov.get('tokens_by_tier')}",
                    "device counters disagree with host accounting"))
            if oobs["trace_events"] <= 0:
                failures.append(_gate_msg(
                    "serving.overload.obs.trace_events",
                    oobs["trace_events"], "> 0",
                    "the overload trace is empty"))
            if not oobs["shadow_rel_err_by_tier"]:
                failures.append(
                    "serving.overload.obs: no shadow rel-err samples — "
                    "estimator-quality telemetry never fired")

    # dedup_by_fill rows (PR 8 format): sorted [int fill, float ratio]
    # pairs — the old object form stringified the int keys and scrambled
    # their order.
    df = srv.get("dedup_by_fill")
    if not isinstance(df, list) or any(
            not (isinstance(f, int) and isinstance(r, (int, float)))
            for f, r in df):
        failures.append(
            "serving: dedup_by_fill must be [[int fill, ratio], ...] rows")
    elif [f for f, _ in df] != sorted(f for f, _ in df):
        failures.append(
            f"serving: dedup_by_fill rows not sorted by fill: "
            f"{[f for f, _ in df]}")
    elif any(not 0.0 < r <= 1.0 for _, r in df):
        failures.append(
            f"serving: dedup_by_fill ratio outside (0, 1] — the probe "
            f"union U/(Q*n_probe) shrinks with batch fill, never grows "
            f"({df})")

    # raw-speed acceptance invariants (PR 8): on the shared-prefix trace,
    # estimator-speculative decoding must BEAT the non-speculative
    # scheduler (wall goodput and, deterministically, tokens per virtual
    # step) for at least one registry draft, with sane acceptance and the
    # two hard invariants intact per draft; the warm prefix cache must
    # actually save replay steps (strictly fewer virtual steps than the
    # cache-off run and saved_replay_steps > 0).
    sp = srv.get("spec")
    if not sp or not sp.get("drafts"):
        failures.append("serving: spec (speculative decoding) section "
                        "missing from artifact")
    else:
        base = sp["nonspec"]
        for d, r in sp["drafts"].items():
            if not r["token_parity"]:
                failures.append(
                    f"serving.spec[{d}]: tokens differ from solo "
                    f"generate() — speculation broke per-request sampling")
            if r["recompiles_after_warmup"] != 0:
                failures.append(
                    f"serving.spec[{d}]: {r['recompiles_after_warmup']} "
                    f"recompiles (variable per-lane acceptance must be "
                    f"data, not shape)")
            if not 0.0 < r["acceptance"] <= 1.0:
                failures.append(
                    f"serving.spec[{d}]: acceptance {r['acceptance']:.3f} "
                    f"outside (0, 1]")
        if not any(r["goodput_tok_s"] > base["goodput_tok_s"]
                   for r in sp["drafts"].values()):
            failures.append(
                f"serving.spec: no draft beats non-speculative goodput "
                f"{base['goodput_tok_s']:.0f} tok/s "
                f"({ {d: round(r['goodput_tok_s']) for d, r in sp['drafts'].items()} })")
        if not any(r["tok_per_step"] > base["tok_per_step"]
                   for r in sp["drafts"].values()):
            failures.append(
                f"serving.spec: no draft beats non-speculative "
                f"tokens-per-step {base['tok_per_step']:.2f}")
    pc = srv.get("prefix_cache")
    if not pc:
        failures.append("serving: prefix_cache section missing from "
                        "artifact")
    else:
        if not pc["token_parity"]:
            failures.append(
                "serving.prefix_cache: tokens differ from solo generate() "
                "— cached-prefix replay skip broke decoding")
        if pc["recompiles_after_warmup"] != 0:
            failures.append(
                f"serving.prefix_cache: {pc['recompiles_after_warmup']} "
                f"recompiles (pool load/save must be compiled once)")
        if not pc["saved_replay_steps"] > 0:
            failures.append(
                "serving.prefix_cache: saved_replay_steps == 0 — the warm "
                "cache never skipped a replay step")
        if not pc["on"]["steps"] < pc["off"]["steps"]:
            failures.append(
                f"serving.prefix_cache: {pc['on']['steps']} virtual steps "
                f"with the cache on >= {pc['off']['steps']} off — cache "
                f"hits are not shortening the replay phase")

    # mesh-scaling acceptance invariants (exact, PR 7): the sharded
    # scheduler step must keep tokens bit-identical to solo generate() and
    # recompile nothing at EVERY mesh shape, and goodput on the virtual
    # step clock (tokens per compiled step — the hardware-independent
    # scaling quantity; wall clock on forced host devices measures core
    # contention, see serving_bench._scaling) must be monotone
    # non-decreasing along the data chain with 8 devices beating 1.
    sc = srv.get("scaling")
    if not sc or not sc.get("rows"):
        failures.append("serving: scaling curve missing from artifact")
    else:
        rows = sc["rows"]
        devices = {r["devices"] for r in rows}
        if not {1, 2, 4, 8} <= devices:
            failures.append(
                f"serving.scaling: curve covers devices {sorted(devices)}, "
                f"needs {{1, 2, 4, 8}}")
        for r in rows:
            shape = f"data={r['data']},model={r['model']}"
            if not r["token_parity"]:
                failures.append(
                    f"serving.scaling[{shape}]: tokens differ from solo "
                    f"generate() — sharding broke per-request sampling")
            if r["recompiles_after_warmup"] != 0:
                failures.append(
                    f"serving.scaling[{shape}]: "
                    f"{r['recompiles_after_warmup']} recompiles after "
                    f"warmup (one executable must serve every mesh shape's "
                    f"traffic)")
            if r["occupancy_steady"] <= 0.5:
                failures.append(
                    f"serving.scaling[{shape}]: steady occupancy "
                    f"{r['occupancy_steady']:.2f} <= 0.5 — replica routing "
                    f"is starving lanes")
        chain = sorted((r["devices"], r["tok_per_step"]) for r in rows
                       if r["model"] == 1)
        if any(b[1] < a[1] for a, b in zip(chain, chain[1:])):
            failures.append(
                f"serving.scaling: tok_per_step not monotone along the "
                f"data chain: {[(d, round(t, 1)) for d, t in chain]}")
        if chain and not chain[-1][1] > chain[0][1]:
            failures.append(
                f"serving.scaling: goodput at 8 devices "
                f"({chain[-1][1]:.1f} tok/step) must beat 1 device "
                f"({chain[0][1]:.1f} tok/step)")

    if failures:
        print("== bench regression check: FAIL ==")
        for f in failures:
            print("  " + f)
    else:
        print("== bench regression check: OK ==")
        for name, sec in (("decode", snap["decode"]),
                          ("estimators", snap["estimators"])):
            for m, row in sec.items():
                print(f"  {name}.{m}: {row['us_per_step']:.0f}us/step "
                      f"({row['tokens_per_s']:.0f} tok/s)")
        print(f"  serving: {srv['goodput_tok_s']:.0f} tok/s goodput "
              f"({srv['speedup_vs_sequential']:.2f}x sequential), "
              f"occupancy {srv['occupancy_steady']:.2f}, p95 "
              f"{srv['p95_token_ms']:.2f}ms")
        lat = srv.get("latency", {})
        if lat:
            print(f"  serving.latency: p99 {lat['p99_token_ms']:.2f}ms, "
                  f"step device {lat['step_device_ms_mean']:.2f}ms + host "
                  f"{lat['step_host_ms_mean']:.2f}ms, tier histograms "
                  f"{sorted(lat['per_tier_cumulative'])}")
        oo = srv.get("obs_overhead", {})
        if oo:
            print(f"  serving.obs: {oo['goodput_ratio_on_vs_off']:.3f}x "
                  f"goodput with obs fully on (parity "
                  f"{oo['token_parity_on_vs_off']}, recompiles "
                  f"{oo['recompiles_after_warmup']})")
        ov = srv.get("overload", {})
        if ov:
            print(f"  serving.overload: shed {ov['shed_rate']:.2f}, p95 "
                  f"{ov['p95_under_overload']:.2f}ms, degraded "
                  f"{ov['degraded_token_frac']:.2f}, queue peak "
                  f"{ov['queue_depth_peak']}/{ov['max_queue']}, "
                  f"recompiles {ov['recompiles_after_warmup']}")
        sp, pc = srv.get("spec", {}), srv.get("prefix_cache", {})
        if sp and pc:
            acc = ", ".join(f"{d}:{r['acceptance']:.2f}"
                            for d, r in sp["drafts"].items())
            print(f"  serving.raw_speed: spec "
                  f"{sp['speedup_vs_nonspec']:.2f}x non-spec goodput "
                  f"(acceptance {acc}); prefix cache saved "
                  f"{pc['saved_replay_steps']} replay steps "
                  f"({pc['on']['steps']} vs {pc['off']['steps']} virtual "
                  f"steps)")
        sc = srv.get("scaling", {})
        if sc.get("rows"):
            curve = ", ".join(
                f"{r['devices']}dev:{r['tok_per_step']:.1f}"
                for r in sc["rows"] if r["model"] == 1)
            print(f"  serving.scaling: tok/step {curve} "
                  f"({sc['goodput_scaling_8v1']:.2f}x at 8 devices, "
                  f"parity+0 recompiles at every shape)")
        print(f"  train: grad floats {trn['grad_float_ratio']:.3f}x fused, "
              f"grad cosine {tm['grad_cosine_vs_full']:.4f}, loss "
              f"{trn['loss_ratio_vs_fused']:.3f}x, refreshes "
              f"{tm['refresh']['count']} (0 recompiles)")
    return len(failures)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slower)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,t1,t2,t3,t4,kernels,roofline,"
                         "decode,estimators,serving,train")
    ap.add_argument("--check", action="store_true",
                    help="compare BENCH_*.json against benchmarks/"
                         "baseline.json; exit 1 on >25%% regression or "
                         "broken wall-clock acceptance invariants")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite benchmarks/baseline.json from the current "
                         "BENCH_*.json artifacts")
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check() else 0)
    if args.update_baseline:
        update_baseline()
        return
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (decode_bench, estimator_bench, fig1_cdf, kernels_bench,
                   roofline, serving_bench, table1_grid, table2_noise,
                   table3_retrieval, table4_lbl, train_bench)

    csv = ["name,us_per_call,derived"]

    def sel(key):
        return only is None or key in only

    if sel("fig1"):
        _, us = fig1_cdf.run(quick=quick)
        csv.append(f"fig1_cdf,{us:.1f},concentration-vs-frequency")
    if sel("t1"):
        _, us = table1_grid.run(quick=quick)
        csv.append(f"table1_grid,{us:.1f},mu-vs-k-l")
    if sel("t2"):
        _, us = table2_noise.run(quick=quick)
        csv.append(f"table2_noise,{us:.1f},noise-robustness")
    if sel("t3"):
        _, us = table3_retrieval.run(quick=quick)
        csv.append(f"table3_retrieval,{us:.1f},rank1-criticality")
    if sel("t4"):
        _, us = table4_lbl.run(quick=quick)
        csv.append(f"table4_lbl,{us:.1f},e2e-lbl-nce")
    if sel("kernels"):
        rows, _ = kernels_bench.run(quick=quick)
        for name, us, derived in rows:
            csv.append(f"{name},{us:.1f},{derived}")
    if sel("roofline"):
        rows, _ = roofline.run(quick=quick)
        csv.append(f"roofline_cells,{len(rows)},see artifacts/roofline.md")
    if sel("decode"):
        rep, us = decode_bench.run(quick=quick)
        csv.append(f"decode_mimps,{us:.1f},"
                   f"speedup_xla={rep['speedup_xla']:.2f}x;"
                   f"bytes_reduction={rep['bytes_reduction']:.1f}x;"
                   f"bound_ok={rep['bound']['ok']}")
    if sel("estimators"):
        rep, us = estimator_bench.run(quick=quick)
        csv.append(f"estimators,{us:.1f},"
                   f"bound_ok_all={rep['bound']['ok_all']};"
                   f"byte_sublinear_all={rep['bound']['byte_sublinear_all']}")
    if sel("serving"):
        rep, us = serving_bench.run(quick=quick)
        csv.append(f"serving,{us:.1f},"
                   f"speedup={rep['speedup_vs_sequential']:.2f}x;"
                   f"occupancy={rep['occupancy_steady']:.2f};"
                   f"parity={rep['token_parity_vs_solo']};"
                   f"recompiles={rep['recompiles_after_warmup']};"
                   f"shed={rep['overload']['shed_rate']:.2f};"
                   f"degraded={rep['overload']['degraded_token_frac']:.2f};"
                   f"scale8v1={rep['scaling']['goodput_scaling_8v1']:.2f}x;"
                   f"spec={rep['spec']['speedup_vs_nonspec']:.2f}x;"
                   f"prefix_saved={rep['prefix_cache']['saved_replay_steps']}")
    if sel("train"):
        rep, us = train_bench.run(quick=quick)
        tm = rep["methods"]["mimps_ce"]
        csv.append(f"train,{us:.1f},"
                   f"grad_floats={rep['grad_float_ratio']:.3f}x;"
                   f"grad_cos={tm['grad_cosine_vs_full']:.4f};"
                   f"loss_ratio={rep['loss_ratio_vs_fused']:.3f};"
                   f"refresh_recompiles="
                   f"{tm['refresh']['refresh_retraces'] - 1}")

    print("\n== CSV ==")
    print("\n".join(csv))


if __name__ == "__main__":
    main()
