"""Benchmark driver — one section per paper table/figure + kernel benches +
the roofline reader. Prints ``name,us_per_call,derived`` CSV lines at the end.

  PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slower)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,t1,t2,t3,t4,kernels,roofline,"
                         "decode,estimators")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (decode_bench, estimator_bench, fig1_cdf, kernels_bench,
                   roofline, table1_grid, table2_noise, table3_retrieval,
                   table4_lbl)

    csv = ["name,us_per_call,derived"]

    def sel(key):
        return only is None or key in only

    if sel("fig1"):
        _, us = fig1_cdf.run(quick=quick)
        csv.append(f"fig1_cdf,{us:.1f},concentration-vs-frequency")
    if sel("t1"):
        _, us = table1_grid.run(quick=quick)
        csv.append(f"table1_grid,{us:.1f},mu-vs-k-l")
    if sel("t2"):
        _, us = table2_noise.run(quick=quick)
        csv.append(f"table2_noise,{us:.1f},noise-robustness")
    if sel("t3"):
        _, us = table3_retrieval.run(quick=quick)
        csv.append(f"table3_retrieval,{us:.1f},rank1-criticality")
    if sel("t4"):
        _, us = table4_lbl.run(quick=quick)
        csv.append(f"table4_lbl,{us:.1f},e2e-lbl-nce")
    if sel("kernels"):
        rows, _ = kernels_bench.run(quick=quick)
        for name, us, derived in rows:
            csv.append(f"{name},{us:.1f},{derived}")
    if sel("roofline"):
        rows, _ = roofline.run(quick=quick)
        csv.append(f"roofline_cells,{len(rows)},see artifacts/roofline.md")
    if sel("decode"):
        rep, us = decode_bench.run(quick=quick)
        csv.append(f"decode_mimps,{us:.1f},"
                   f"bytes_reduction={rep['bytes_reduction']:.1f}x;"
                   f"bound_ok={rep['bound']['ok']}")
    if sel("estimators"):
        rep, us = estimator_bench.run(quick=quick)
        csv.append(f"estimators,{us:.1f},"
                   f"bound_ok_all={rep['bound']['ok_all']};"
                   f"byte_sublinear_all={rep['bound']['byte_sublinear_all']}")

    print("\n== CSV ==")
    print("\n".join(csv))


if __name__ == "__main__":
    main()
