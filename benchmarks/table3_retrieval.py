"""Paper Table 3: deterministic retrieval errors — drop the rank-1 / rank-2 /
both neighbors from S_k and measure the damage (rank-1 loss is catastrophic,
the paper's key indexing-quality finding)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import exact_log_z, mimps_log_z, mince_log_z

from .common import make_embeddings, make_queries, pct_abs_rel_error


def run(n=20000, d=64, n_queries=100, quick=False):
    if quick:
        n, n_queries = 8000, 50
    key = jax.random.PRNGKey(0)
    kv, kq, ke = jax.random.split(key, 3)
    v = make_embeddings(kv, n, d)
    q, _ = make_queries(kq, v, n_queries)
    lz_true = jax.vmap(lambda qq: exact_log_z(v, qq))(q)
    keys = jax.random.split(ke, n_queries)
    t0 = time.perf_counter()

    cases = {"None": None, "1": (0,), "2": (1,), "[1 2]": (0, 1)}
    out = []
    print("\n== Table 3 (paper MIMPS: None 0.8 | drop-1 39.3 | drop-2 6.1 | "
          "drop-both 45.0; MINCE flat 133.7) ==")
    print(f"{'method':8s} " + " ".join(f"{c:>12s}" for c in cases))
    rows = {"MIMPS": [], "MINCE": []}
    for cname, dr in cases.items():
        lz = jax.vmap(lambda qq, kk: mimps_log_z(
            v, qq, 1000, 1000, kk, drop_ranks=dr))(q, keys)
        rows["MIMPS"].append(pct_abs_rel_error(lz, lz_true))
        lz = jax.vmap(lambda qq, kk: mince_log_z(v, qq, 1, 1000, kk))(q, keys)
        rows["MINCE"].append(pct_abs_rel_error(lz, lz_true))
    elapsed = time.perf_counter() - t0
    for m, errs in rows.items():
        cells = []
        for cname, e in zip(cases, errs):
            mu = float(np.mean(e))
            cells.append(f"{mu:12.2f}")
            out.append({"method": m, "ret_err": cname, "mu": mu})
        print(f"{m:8s} " + " ".join(cells))
    return out, elapsed * 1e6 / (len(cases) * 2 * n_queries)
