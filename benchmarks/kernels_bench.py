"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python), so
their wall time is not meaningful; what we measure here is
 (a) the XLA streaming implementations that share the kernels' algorithm
     (fused CE / streaming LSE) vs the naive materialize-everything oracle —
     a real, timed memory-traffic win even on CPU;
 (b) derived bytes-saved per call for the Pallas kernels from their block
     geometry (the TPU-side value proposition).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import fused_ce_ref
from repro.serve.output_layer import streaming_logz_argmax
from repro.train.losses import streaming_ce
from repro.core.mince import solver_convergence_trace


def _time(fn, *args, reps=5):
    fn(*args)                      # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(quick=False):
    t, d, v = (2048, 256, 32768) if not quick else (512, 128, 8192)
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (t, d)) * 0.3
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.3
    lab = jax.random.randint(jax.random.fold_in(key, 2), (t,), 0, v)
    out = []

    naive = jax.jit(lambda h, w: fused_ce_ref(h, w, lab)[0].mean())
    fused = jax.jit(lambda h, w: streaming_ce(h, w, lab,
                                              backend="xla")[0].mean())
    tn = _time(naive, h, w)
    tf = _time(fused, h, w)
    logits_bytes = t * v * 4
    out.append(("ce_naive", tn * 1e6, f"logits_hbm={logits_bytes/1e6:.0f}MB"))
    out.append(("ce_streaming_xla", tf * 1e6,
                f"logits_hbm=0;speedup={tn/tf:.2f}x"))

    g_naive = jax.jit(jax.grad(lambda w: fused_ce_ref(h, w, lab)[0].mean()))
    g_fused = jax.jit(jax.grad(
        lambda w: streaming_ce(h, w, lab, backend="xla")[0].mean()))
    tn = _time(g_naive, w)
    tf = _time(g_fused, w)
    out.append(("ce_naive_grad", tn * 1e6, "materializes softmax"))
    out.append(("ce_streaming_grad", tf * 1e6, f"speedup={tn/tf:.2f}x"))

    dec_naive = jax.jit(lambda h, w: (
        jax.nn.logsumexp(h @ w.T, -1), jnp.argmax(h @ w.T, -1)))
    dec_stream = jax.jit(lambda h, w: streaming_logz_argmax(h, w))
    hq = h[:128]
    tn = _time(dec_naive, hq, w)
    tf = _time(dec_stream, hq, w)
    out.append(("decode_logz_naive", tn * 1e6, ""))
    out.append(("decode_logz_streaming", tf * 1e6, f"speedup={tn/tf:.2f}x"))

    # Pallas kernels: interpret-mode correctness is covered by tests; derive
    # the TPU-side traffic savings from geometry.
    out.append(("pallas_fused_ce", float("nan"),
                f"hbm_saved_per_step={t*v*4/1e6:.0f}MB(logits)"))
    out.append(("pallas_ivf_score", float("nan"),
                f"vocab_bytes_read=1/{v//(8*512) if v>=8*512 else 1} of full"))

    # MINCE solver: Halley vs Newton iterations-to-converge (paper SS4.2)
    rng = np.random.RandomState(0)
    alpha = jnp.array(rng.randn(200) + 6.0, jnp.float32)
    beta = jnp.array(rng.randn(200), jnp.float32)
    for solver in ("halley", "newton"):
        its = []
        for th0 in (-20.0, -10.0, 0.0, 15.0, 30.0):   # far-from-root starts
            tr = np.asarray(solver_convergence_trace(
                alpha, beta, jnp.float32(th0), 60, solver=solver))
            its.append(int(np.argmax(tr < 1e-3)) if (tr < 1e-3).any() else 60)
        out.append((f"mince_{solver}", float("nan"),
                    f"iters_to_1e-3={its} (5 starts)"))

    print("\n== Kernel benches ==")
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")
    return out, 0.0
