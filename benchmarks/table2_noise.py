"""Paper Table 2: estimator robustness to query noise (0/10/20/30% relative
norm) — MIMPS should be nearly flat; Uniform stays ~100%."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import exact_log_z, mimps_log_z, mince_log_z, uniform_log_z
from repro.core.feature_maps import build_fmbe, make_feature_map, \
    fmbe_estimate_z

from .common import make_embeddings, make_queries, pct_abs_rel_error


def run(n=20000, d=64, n_queries=100, quick=False, fmbe_features=16384):
    if quick:
        n, n_queries, fmbe_features = 8000, 50, 8192
    key = jax.random.PRNGKey(0)
    kv, kq, ke, kf = jax.random.split(key, 4)
    v = make_embeddings(kv, n, d)
    fm = make_feature_map(kf, d, fmbe_features)
    fmbe_state = build_fmbe(fm, v)
    t0 = time.perf_counter()
    results = {}
    for noise in (0.0, 0.1, 0.2, 0.3):
        q, _ = make_queries(kq, v, n_queries, noise_rel=noise)
        lz_true = jax.vmap(lambda qq: exact_log_z(v, qq))(q)
        keys = jax.random.split(ke, n_queries)
        row = {}
        lz = jax.vmap(lambda qq, kk: uniform_log_z(v, qq, 1000, kk))(q, keys)
        row["Uniform"] = pct_abs_rel_error(lz, lz_true)
        lz = jax.vmap(lambda qq, kk: mimps_log_z(v, qq, 1000, 1000, kk))(
            q, keys)
        row["MIMPS"] = pct_abs_rel_error(lz, lz_true)
        lz = jax.vmap(lambda qq, kk: mince_log_z(
            v, qq, 1, 1000, kk, weighting="paper"))(q, keys)
        row["MINCE"] = pct_abs_rel_error(lz, lz_true)
        zf = jax.vmap(lambda qq: fmbe_estimate_z(fmbe_state, qq))(q)
        zt = np.exp(np.asarray(lz_true, np.float64))
        row["FMBE"] = 100.0 * np.abs((np.asarray(zf, np.float64) - zt) / zt)
        results[noise] = row
    elapsed = time.perf_counter() - t0

    print("\n== Table 2 (paper: MIMPS 0.8->0.9 across noise; FMBE ~84-87; "
          "Uniform ~102-105) ==")
    methods = ["Uniform", "MIMPS", "MINCE", "FMBE"]
    print(f"{'method':8s} " + " ".join(f"{int(100*nz):>3d}%mu {'sig':>6s}"
                                       for nz in results))
    out = []
    for m in methods:
        cells = []
        for nz, row in results.items():
            mu = float(np.mean(row[m]))
            sg = float(np.std(row[m]) / np.sqrt(len(row[m])))
            cells.append(f"{mu:6.1f} {sg:6.2f}")
            out.append({"method": m, "noise": nz, "mu": mu, "sigma": sg})
        print(f"{m:8s} " + " ".join(cells))
    return out, elapsed * 1e6 / (4 * 4 * n_queries)
