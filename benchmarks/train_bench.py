"""Training-path benchmark: estimator-backed CE vs the fused full-vocab CE,
tracked in ``BENCH_train.json`` from PR 5 onward.

Trains the SAME reduced model from the SAME init on the synthetic corpus
twice — once with ``fused_ce`` (streaming full-vocab softmax) and once with
``mimps_ce`` (Eq. 5 estimator in the gradient, device-resident IVF index
refreshed every K steps) — and records the two claims the PR-5 acceptance
gates (``benchmarks/run.py --check``):

  * **Sublinear embedding-grad floats.** ``mimps_ce``'s backward scatter-
    adds into the scored head/tail/label rows only. ``grad_scored_ratio``
    is the static ceiling (min(T*n_probe, nb)*br + l + T) / V — every row
    the backward can possibly touch, counted against the full V rows
    ``fused_ce`` writes; ``grad_unique_ratio`` is the measured unique-row
    fraction on a real batch. Gate: scored ratio < 0.35.

  * **Zero recompiles across refreshes.** Both the train step and the
    refresh are shape-static (``mips.pack_ivf`` fixed capacity): after
    warmup, N refreshes retrace NOTHING. The churn/drift trajectory is
    recorded so an index that silently stops adapting shows up in review.

  * **Gradient fidelity.** cosine(full-CE embedding grad, mimps_ce grad)
    on the touched rows >= 0.99 at quick scale, and the final loss within
    5% of ``fused_ce`` after the step budget — estimating Z in the
    gradient must not change what the model learns. The loss comparison
    uses an EXACT full-vocab CE on held-out batches (the per-step metric
    mimps_ce reports is itself an estimate; gating on it would conflate
    estimator noise with learning). The 5% parity is a quick-scale gate:
    at ``--full`` scale (64k vocab, 60 steps) sparse negatives push the
    partition down more slowly early in training, so parity needs a larger
    step budget than a CI bench affords — the full artifact records the
    gap rather than gating it.

Wall-clock (tokens/s) is recorded for trend-tracking; on this CPU container
the fused scan and the sparse gather have very different XLA lowerings, so
the byte/float ratios — which are exact — carry the acceptance, like the
decode bench's byte accounting.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import TrainConfig
from repro.core import ivf_capacity_blocks
from repro.core.decode import make_plan
from repro.data import DataIterator, SyntheticCorpus
from repro.models import Model
from repro.train import init_train_state, make_train_step
from repro.train.losses import _flatten_head, estimator_ce


def _cfg(quick: bool):
    # sized so the scored-support ceiling (min(T*n_probe, nb)*br + l + T)/V
    # stays < 0.35 at the bench's OWN token batch in both modes — the gate
    # this artifact must satisfy (quick: 0.267 at T=32; full: 0.267 at
    # T=64 with twice the vocab and tail)
    vocab, br, n_probe, l = (32768, 64, 4, 512) if quick else \
        (65536, 64, 4, 1024)
    cfg = reduced_config("qwen1.5-4b")
    return dataclasses.replace(
        cfg, vocab=vocab,
        partition=dataclasses.replace(
            cfg.partition, block_rows=br, n_probe=n_probe, l=l,
            n_clusters=0))          # 0 -> derived V/(4*br)


def _counted(fn):
    """jit wrapper whose python body counts (re)traces."""
    count = {"n": 0}

    def inner(*args):
        count["n"] += 1
        return fn(*args)

    return jax.jit(inner), count


def _train_run(cfg, loss, steps, batch, seq, refresh_every=0, seed=0):
    model = Model(cfg)
    tc = TrainConfig(lr=1e-3, loss=loss, total_steps=steps, seed=seed,
                     warmup_steps=max(1, steps // 10))
    state = init_train_state(model, tc, jax.random.PRNGKey(seed))
    step_fn, step_traces = _counted(make_train_step(model, tc))
    refresh_fn = None
    churn, drift = [], []
    refresh_traces = {"n": 0}
    if refresh_every:
        # same body make_index_refresh jits, wrapped so retraces are counted
        if loss == "lsh_ce":
            from repro.core.lsh import rehash_lsh

            def refresh_body(index, params):
                return rehash_lsh(index, model.head_matrix(params))
        else:
            from repro.core import refresh_ivf
            from repro.train.train_loop import _resolve_n_clusters
            n_clusters = _resolve_n_clusters(cfg)

            def refresh_body(index, params):
                return refresh_ivf(index, model.head_matrix(params),
                                   n_clusters=n_clusters)

        _refresh_jit, refresh_traces = _counted(refresh_body)

        def refresh_fn(state):
            new_index, m = _refresh_jit(state.index, state.params)
            return state._replace(index=new_index), m

    it = DataIterator(SyntheticCorpus(vocab=cfg.vocab, seed=seed),
                      batch, seq)
    losses, t_measure = [], None
    warm = 2
    for i in range(steps):
        toks, labels = next(it)
        b = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if refresh_fn is not None and i and i % refresh_every == 0:
            state, rm = refresh_fn(state)
            jax.block_until_ready(rm["churn"])
            churn.append(float(rm["churn"]))
            drift.append(float(rm["drift"]))
        if i == warm:
            t_measure = time.perf_counter()
        state, met = step_fn(state, b)
        jax.block_until_ready(met["loss_total"])
        losses.append(float(met["loss_total"]))
    elapsed = time.perf_counter() - t_measure
    tokens = batch * seq * (steps - warm)
    return {
        "model": model, "tc": tc, "state": state, "losses": losses,
        "final_loss": float(np.mean(losses[-5:])),
        "tokens_per_s": tokens / elapsed,
        "us_per_step": 1e6 * elapsed / (steps - warm),
        "churn": churn, "drift": drift,
        "step_retraces": step_traces["n"],
        "refresh_retraces": refresh_traces["n"],
    }


def _exact_eval_loss(cfg, run, n_batches=4, seed=99):
    """Full-vocab CE of a trained run on held-out synthetic batches — the
    estimator-free yardstick both methods are compared on."""
    model, state = run["model"], run["state"]
    it = DataIterator(SyntheticCorpus(vocab=cfg.vocab, seed=seed), 4, 8)
    tot = []
    for _ in range(n_batches):
        toks, labels = next(it)
        hidden, _ = model.forward(state.params, jnp.asarray(toks))
        h2, w, lab = _flatten_head(model, state.params, hidden,
                                   jnp.asarray(labels))
        logits = (h2 @ w.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        s = jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]
        tot.append(float((lse - s).mean()))
    return float(np.mean(tot))


def _grad_fidelity(cfg, batch, seq, seed=0):
    """cosine(full-CE dw, mimps_ce dw) on touched rows + measured unique-row
    ratio, on a real (model-forward) batch at the shared init — SAME
    (batch, seq) as the timed runs, so the reported ratios describe the
    benchmarked step."""
    model = Model(cfg)
    tc = TrainConfig(lr=1e-3, loss="mimps_ce", seed=seed)
    state = init_train_state(model, tc, jax.random.PRNGKey(seed))
    index = state.index
    it = DataIterator(SyntheticCorpus(vocab=cfg.vocab, seed=seed), batch,
                      seq)
    toks, labels = next(it)
    hidden, _ = model.forward(state.params, jnp.asarray(toks))
    h2, w, lab = _flatten_head(model, state.params, hidden,
                               jnp.asarray(labels))
    key = jax.random.PRNGKey(seed + 7)
    pc = cfg.partition

    def full(h, w):
        logits = (h @ w.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        s = jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]
        return (lse - s).mean()

    def est(h, w):
        nll, _, _ = estimator_ce(index, h, w, lab, key,
                                 n_probe=pc.n_probe, l=pc.l)
        return nll.mean()

    gw0 = np.asarray(jax.grad(full, argnums=1)(h2, w))
    gw1 = np.asarray(jax.grad(est, argnums=1)(h2, w))
    touched = np.abs(gw1).sum(-1) > 0
    a, b = gw0[touched].ravel(), gw1[touched].ravel()
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))

    plan = make_plan(index, h2, key, pc.n_probe, pc.l)
    t = h2.shape[0]
    br = index.v_blocks.shape[1]
    u = int(plan.head_live)
    v = w.shape[0]
    unique_ratio = (u * br + pc.l + t) / v
    scored_blocks = min(t * pc.n_probe, index.n_blocks)
    scored_ratio = (scored_blocks * br + pc.l + t) / v
    return {"grad_cosine_vs_full": cos,
            "grad_unique_ratio": float(unique_ratio),
            "grad_scored_ratio": float(scored_ratio),
            "rows_touched": int(touched.sum()), "vocab": v,
            "head_live_blocks": u}


def _refresh_cost(cfg, rows_updated=256, seed=0):
    """Index-maintenance cost at equal churn: perturb R embedding rows, then
    pay each backend's maintenance primitive. IVF has no per-row splice — a
    churned index must re-cluster + repack (O(V) assignment work even for
    kmeans_iters=0 wiring), while the LSH tables splice exactly the R
    touched rows (``update_rows``, O(R * L * cap)). This is the update-cost
    claim behind the ``lsh_ce`` training path: refresh cadence can track
    optimizer churn instead of amortizing a full rebuild. Interleaved
    best-of timing, same discipline as the decode benches."""
    from benchmarks.common import time_fns
    from repro.core import build_ivf_device, refresh_ivf
    from repro.core.lsh import build_lsh_device, update_rows
    from repro.train.train_loop import _resolve_n_clusters
    pc = cfg.partition
    v, d = cfg.vocab, cfg.d_model
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (v, d), jnp.float32) / jnp.sqrt(d)
    n_clusters = _resolve_n_clusters(cfg)
    ivf = build_ivf_device(jax.random.fold_in(key, 1), w,
                           block_rows=pc.block_rows, n_clusters=n_clusters)
    lsh = build_lsh_device(jax.random.fold_in(key, 2), w,
                           n_bits=pc.lsh_bits, n_tables=pc.lsh_tables,
                           bucket_cap=pc.lsh_bucket_cap,
                           mips_scale=pc.lsh_mips_scale,
                           tail_beta=pc.lsh_tail_beta)
    rows = jax.random.choice(jax.random.fold_in(key, 3), v,
                             (rows_updated,), replace=False).astype(jnp.int32)
    w2 = w.at[rows].add(
        0.1 * jax.random.normal(jax.random.fold_in(key, 4), (rows_updated, d)))

    ivf_fn = jax.jit(lambda idx, ww: refresh_ivf(idx, ww,
                                                 n_clusters=n_clusters)[0])
    lsh_fn = jax.jit(lambda idx, ww: update_rows(idx, ww, rows))
    t_ivf, t_lsh = time_fns([(ivf_fn, (ivf, w2)), (lsh_fn, (lsh, w2))],
                            reps=15)
    return {"ivf_refresh_us": t_ivf * 1e6, "lsh_update_us": t_lsh * 1e6,
            "rows_updated": rows_updated, "ratio": t_lsh / t_ivf}


def run(quick=True, out_path="BENCH_train.json"):
    cfg = _cfg(quick)
    steps, batch, seq = (30, 4, 8) if quick else (60, 8, 8)
    refresh_every = 8 if quick else 16
    t0 = time.perf_counter()

    fused = _train_run(cfg, "fused_ce", steps, batch, seq)
    mimps = _train_run(cfg, "mimps_ce", steps, batch, seq,
                       refresh_every=refresh_every)
    lsh = _train_run(cfg, "lsh_ce", steps, batch, seq,
                     refresh_every=refresh_every)
    fidelity = _grad_fidelity(cfg, batch, seq)
    refresh_cost = _refresh_cost(cfg)

    eval_fused = _exact_eval_loss(cfg, fused)
    eval_mimps = _exact_eval_loss(cfg, mimps)
    eval_lsh = _exact_eval_loss(cfg, lsh)
    loss_ratio = eval_mimps / eval_fused
    pc = cfg.partition
    report = {
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "block_rows": pc.block_rows, "n_probe": pc.n_probe, "l": pc.l,
            "n_blocks": ivf_capacity_blocks(
                cfg.vocab, pc.block_rows,
                max(1, cfg.vocab // (4 * pc.block_rows))),
            "steps": steps, "tokens_per_step": batch * seq,
            "refresh_every": refresh_every,
        },
        "methods": {
            "fused_ce": {**{k: fused[k] for k in
                            ("tokens_per_s", "us_per_step", "final_loss")},
                         "exact_eval_loss": eval_fused},
            "mimps_ce": {
                **{k: mimps[k] for k in
                   ("tokens_per_s", "us_per_step", "final_loss")},
                "exact_eval_loss": eval_mimps,
                **fidelity,
                "refresh": {
                    "churn": mimps["churn"], "drift": mimps["drift"],
                    "count": len(mimps["churn"]),
                    "step_retraces": mimps["step_retraces"],
                    "refresh_retraces": mimps["refresh_retraces"]},
            },
            "lsh_ce": {
                **{k: lsh[k] for k in
                   ("tokens_per_s", "us_per_step", "final_loss")},
                "exact_eval_loss": eval_lsh,
                "refresh": {
                    "churn": lsh["churn"], "drift": lsh["drift"],
                    "count": len(lsh["churn"]),
                    "step_retraces": lsh["step_retraces"],
                    "refresh_retraces": lsh["refresh_retraces"]},
            },
        },
        "refresh_cost": refresh_cost,
        "loss_ratio_vs_fused": loss_ratio,
        "lsh_loss_ratio_vs_fused": eval_lsh / eval_fused,
        "lsh_zero_refresh_recompiles":
            lsh["step_retraces"] == 1 and lsh["refresh_retraces"] == 1,
        "grad_float_ratio": fidelity["grad_scored_ratio"],
        "zero_refresh_recompiles":
            mimps["step_retraces"] == 1 and mimps["refresh_retraces"] == 1,
        "loss_curves": {"fused_ce": fused["losses"],
                        "mimps_ce": mimps["losses"],
                        "lsh_ce": lsh["losses"]},
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    us = 1e6 * (time.perf_counter() - t0)
    print(f"train bench: grad_float_ratio "
          f"{report['grad_float_ratio']:.3f} (unique "
          f"{fidelity['grad_unique_ratio']:.3f}), grad_cosine "
          f"{fidelity['grad_cosine_vs_full']:.4f}, loss ratio "
          f"{loss_ratio:.3f}, refresh churn {mimps['churn']}, "
          f"recompiles step={mimps['step_retraces'] - 1} "
          f"refresh={mimps['refresh_retraces'] - 1}")
    return report, us


if __name__ == "__main__":
    run()
